"""AOT pipeline: lower the L2 model to HLO **text** for the Rust runtime.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage (from the Makefile):  python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: str) -> dict:
    """Lower sched_step and write the artifact + shape metadata.

    Returns a manifest dict {filename: path}.
    """
    os.makedirs(out_dir, exist_ok=True)
    lowered = jax.jit(model.sched_step).lower(*model.example_args())
    hlo = to_hlo_text(lowered)
    manifest = {}

    hlo_path = os.path.join(out_dir, "sched_step.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    manifest["sched_step.hlo.txt"] = hlo_path

    # Shape contract consumed by rust/src/runtime/accel.rs at load time.
    meta_path = os.path.join(out_dir, "sched_step.meta")
    with open(meta_path, "w") as f:
        f.write(
            "jobs={}\nfactors={}\nspots={}\nnodes={}\n".format(
                model.JOBS, model.FACTORS, model.SPOTS, model.NODES
            )
        )
    manifest["sched_step.meta"] = meta_path
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    manifest = build_artifacts(args.out)
    for name, path in sorted(manifest.items()):
        print(f"wrote {name} -> {path} ({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()
