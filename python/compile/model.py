"""L2 JAX model: the batched scheduling decision step.

``sched_step`` composes the three L1 Pallas kernels into the computation the
Rust scheduler offloads per cycle:

  1. multifactor priority scores for the pending queue,
  2. LIFO preemption victim selection over running spot jobs,
  3. job x node feasibility counts.

The function is jitted and AOT-lowered once (``aot.py``) to HLO text with
**fixed shapes** (XLA requires static shapes); the Rust side pads its
batches to these sizes. Keep the constants in sync with
``rust/src/sched/priority.rs`` and ``rust/src/runtime/accel.rs``.
"""

import jax
import jax.numpy as jnp

from compile.kernels import fit, preempt_select, priority

# ---- AOT shape contract (mirrored in rust/src/runtime/accel.rs) ----------
JOBS = 1024  # max pending jobs scored per cycle
FACTORS = 8  # priority factor width (rust sched::priority::N_FACTORS)
SPOTS = 1024  # max running spot jobs considered for preemption
NODES = 1024  # max nodes in the fit computation

# Weight vector — must match rust sched::priority::WEIGHTS.
# [qos, age, size, requeue, partition, fairshare, reserved, reserved]
WEIGHTS = jnp.array([1000.0, 1.0, 0.1, 5.0, 10.0, -50.0, 0.0, 0.0], jnp.float32)


def sched_step(factors, weights, spot_cores, demand, free, reqs):
    """One batched scheduling decision step.

    Args:
      factors: f32[JOBS, FACTORS] priority factors (zero rows = padding).
      weights: f32[FACTORS] priority weights.
      spot_cores: f32[SPOTS] cores of running spot jobs, youngest-first
        (zeros = padding).
      demand: f32[1] cores the preemption must free (0 = no preemption).
      free: f32[NODES] free cores per node (zeros = busy/padding).
      reqs: f32[JOBS] per-node core requirement per job (1e18 = padding).

    Returns:
      (scores f32[JOBS], preempt_mask i32[SPOTS], fit_counts i32[JOBS])
    """
    scores = priority.priority_scores(factors, weights)
    mask = preempt_select.select_victims(spot_cores, demand)
    counts = fit.fit_counts(free, reqs)
    return scores, mask, counts


def example_args():
    """ShapeDtypeStructs for AOT lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((JOBS, FACTORS), f32),
        jax.ShapeDtypeStruct((FACTORS,), f32),
        jax.ShapeDtypeStruct((SPOTS,), f32),
        jax.ShapeDtypeStruct((1,), f32),
        jax.ShapeDtypeStruct((NODES,), f32),
        jax.ShapeDtypeStruct((JOBS,), f32),
    )
