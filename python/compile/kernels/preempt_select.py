"""L1 Pallas kernel: LIFO preemption victim selection.

Given running spot jobs ordered **youngest-first** (the paper's
"last-in, first-out" / Slurm ``preempt_youngest_first`` order) with their
core counts, select the minimal prefix whose cumulative cores cover the
demand:

    mask[i] = (exclusive_cumsum(cores)[i] < demand) AND (cores[i] > 0)

Padding entries carry ``cores == 0`` and are never selected. The whole
vector fits one VMEM block (1024 x 4B = 4 KiB), so the kernel is a single
grid step doing a scan + compare — on TPU this is a VPU prefix-sum.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _select_kernel(cores_ref, demand_ref, mask_ref):
    cores = cores_ref[...]
    demand = demand_ref[0]
    cum = jnp.cumsum(cores)
    exclusive = cum - cores
    mask_ref[...] = ((exclusive < demand) & (cores > 0)).astype(jnp.int32)


@jax.jit
def select_victims(cores_youngest_first, demand):
    """LIFO victim mask.

    Args:
      cores_youngest_first: f32[N] core counts of running spot jobs, ordered
        youngest-first; zero entries are padding.
      demand: f32[1] cores that must be freed.

    Returns:
      i32[N] 0/1 mask over the input order (1 = preempt).
    """
    (n,) = cores_youngest_first.shape
    return pl.pallas_call(
        _select_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(cores_youngest_first.astype(jnp.float32), demand.astype(jnp.float32))
