"""L1 Pallas kernel: job x node feasibility counting.

For each pending job, count the nodes whose free cores satisfy the job's
per-node requirement:

    counts[j] = sum_m (free[m] >= req[j])

The scheduler uses the counts to short-circuit allocation attempts for jobs
with zero feasible nodes. Tiled over the job axis; the free-core vector
stays resident in VMEM across grid steps (1024 x 4B = 4 KiB), and each grid
step materializes a (BLOCK_JOBS, NODES) compare block (256 x 1024 = 256 KiB
as i1/f32 intermediates) — comfortably inside a TPU core's ~16 MiB VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_JOBS = 256


def _fit_kernel(free_ref, req_ref, out_ref):
    free = free_ref[...]  # (M,)
    req = req_ref[...]  # (B,)
    out_ref[...] = jnp.sum(
        (free[None, :] >= req[:, None]).astype(jnp.int32), axis=1
    )


@jax.jit
def fit_counts(free, reqs):
    """Count feasible nodes per job.

    Args:
      free: f32[M] free cores per node (0 for busy/padding nodes).
      reqs: f32[N] per-node core requirement per job (padding jobs should
        carry a requirement larger than any node, e.g. 1e9, so their count
        is 0).

    Returns:
      i32[N] feasible-node counts.
    """
    (m,) = free.shape
    (n,) = reqs.shape
    block = min(BLOCK_JOBS, n)
    pad = (-n) % block
    if pad:
        reqs = jnp.pad(reqs, (0, pad), constant_values=jnp.float32(1e18))
    padded_n = n + pad
    grid = (padded_n // block,)
    out = pl.pallas_call(
        _fit_kernel,
        out_shape=jax.ShapeDtypeStruct((padded_n,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(free.astype(jnp.float32), reqs.astype(jnp.float32))
    return out[:n]
