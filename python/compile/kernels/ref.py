"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest (and hypothesis sweeps) assert
``assert_allclose(kernel(x), ref(x))`` for every kernel over randomized
shapes and values. They are also the semantic contract the Rust fallback
(`rust/src/runtime/fallback.rs`) implements — the cargo equivalence test
closes the loop.
"""

import jax.numpy as jnp


def priority_scores_ref(factors, weights):
    """scores = factors @ weights."""
    return jnp.asarray(factors, jnp.float32) @ jnp.asarray(weights, jnp.float32)


def select_victims_ref(cores_youngest_first, demand):
    """Minimal LIFO prefix covering the demand (see preempt_select.py)."""
    cores = jnp.asarray(cores_youngest_first, jnp.float32)
    demand = jnp.asarray(demand, jnp.float32)
    cum = jnp.cumsum(cores)
    exclusive = cum - cores
    return ((exclusive < demand[0]) & (cores > 0)).astype(jnp.int32)


def fit_counts_ref(free, reqs):
    """counts[j] = #{m : free[m] >= reqs[j]}."""
    free = jnp.asarray(free, jnp.float32)
    reqs = jnp.asarray(reqs, jnp.float32)
    return jnp.sum(free[None, :] >= reqs[:, None], axis=1).astype(jnp.int32)
