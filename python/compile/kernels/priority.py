"""L1 Pallas kernel: batched multifactor priority scoring.

Computes ``scores = sum(factors * weights, axis=1)`` over a padded
``(JOBS, FACTORS)`` factor matrix — the per-cycle computation Slurm's
priority/multifactor plugin does per pending job, batched.

TPU mapping (DESIGN.md §Hardware-Adaptation): the factor matrix tiles into
VMEM as ``(BLOCK, FACTORS)`` f32 blocks (256x8x4B = 8 KiB per block) with the
weight vector resident; the reduction is a VPU-friendly multiply-add. Pallas
runs in ``interpret=True`` everywhere in this repo because the CPU PJRT
client cannot execute Mosaic custom-calls; on a real TPU the same kernel
lowers to Mosaic unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 256 keeps the block in VMEM with generous headroom.
BLOCK_JOBS = 256


def _priority_kernel(f_ref, w_ref, o_ref):
    """One block: (B, F) factors x (F,) weights -> (B,) scores."""
    f = f_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.sum(f * w[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=())
def priority_scores(factors, weights):
    """Score every job: ``factors @ weights``.

    Args:
      factors: f32[N, F] factor matrix (N padded to a multiple of BLOCK_JOBS
        by the caller or handled here by an internal pad).
      weights: f32[F] weight vector.

    Returns:
      f32[N] scores.
    """
    n, f = factors.shape
    block = min(BLOCK_JOBS, n)
    pad = (-n) % block
    if pad:
        factors = jnp.pad(factors, ((0, pad), (0, 0)))
    padded_n = n + pad
    grid = (padded_n // block,)
    out = pl.pallas_call(
        _priority_kernel,
        out_shape=jax.ShapeDtypeStruct((padded_n,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(factors.astype(jnp.float32), weights.astype(jnp.float32))
    return out[:n]
