"""AOT pipeline smoke tests: HLO text artifact generation."""

import os

from compile import aot, model


def test_build_artifacts(tmp_path):
    manifest = aot.build_artifacts(str(tmp_path))
    hlo_path = manifest["sched_step.hlo.txt"]
    assert os.path.exists(hlo_path)
    text = open(hlo_path).read()
    # HLO text format, not a serialized proto.
    assert text.lstrip().startswith("HloModule")
    # The three outputs come back as one tuple.
    assert "f32[%d,%d]" % (model.JOBS, model.FACTORS) in text

    meta = open(manifest["sched_step.meta"]).read()
    assert f"jobs={model.JOBS}" in meta
    assert f"factors={model.FACTORS}" in meta


def test_artifacts_are_deterministic(tmp_path):
    a = aot.build_artifacts(str(tmp_path / "a"))
    b = aot.build_artifacts(str(tmp_path / "b"))
    ta = open(a["sched_step.hlo.txt"]).read()
    tb = open(b["sched_step.hlo.txt"]).read()
    assert ta == tb
