"""Kernel-vs-reference correctness: the core L1 signal.

Each Pallas kernel is checked against its pure-jnp oracle (ref.py) over
hypothesis-swept shapes and values, plus directed edge cases.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import fit, preempt_select, priority, ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=30, derandomize=True
)
hypothesis.settings.load_profile("kernels")


# ---- priority -------------------------------------------------------------


@hypothesis.given(
    n=st.integers(1, 700),
    f=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_priority_matches_ref(n, f, seed):
    rng = np.random.default_rng(seed)
    factors = rng.normal(size=(n, f)).astype(np.float32)
    weights = rng.normal(size=(f,)).astype(np.float32)
    got = priority.priority_scores(jnp.asarray(factors), jnp.asarray(weights))
    want = ref.priority_scores_ref(factors, weights)
    assert got.shape == (n,)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_priority_block_boundary_shapes():
    # Exactly one block, one block + 1, multiple of block.
    for n in [priority.BLOCK_JOBS, priority.BLOCK_JOBS + 1, 4 * priority.BLOCK_JOBS]:
        factors = np.ones((n, 8), np.float32)
        weights = np.arange(8, dtype=np.float32)
        got = priority.priority_scores(jnp.asarray(factors), jnp.asarray(weights))
        assert_allclose(np.asarray(got), np.full(n, weights.sum()), rtol=1e-6)


def test_priority_zero_rows_score_zero():
    factors = np.zeros((10, 8), np.float32)
    weights = np.ones(8, np.float32)
    got = priority.priority_scores(jnp.asarray(factors), jnp.asarray(weights))
    assert_allclose(np.asarray(got), np.zeros(10), atol=0)


# ---- preempt_select --------------------------------------------------------


@hypothesis.given(
    n=st.integers(1, 600),
    demand_frac=st.floats(0.0, 1.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_select_matches_ref(n, demand_frac, seed):
    rng = np.random.default_rng(seed)
    cores = rng.integers(0, 512, size=n).astype(np.float32)
    demand = np.array([demand_frac * cores.sum()], np.float32)
    got = preempt_select.select_victims(jnp.asarray(cores), jnp.asarray(demand))
    want = ref.select_victims_ref(cores, demand)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@hypothesis.given(
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_select_is_minimal_lifo_prefix(n, seed):
    """Property: the mask is a prefix of the non-padding entries, it covers
    the demand, and dropping its last selected job would not."""
    rng = np.random.default_rng(seed)
    cores = rng.integers(1, 512, size=n).astype(np.float32)  # no padding here
    demand_val = float(rng.integers(1, int(cores.sum()) + 1))
    demand = np.array([demand_val], np.float32)
    mask = np.asarray(
        preempt_select.select_victims(jnp.asarray(cores), jnp.asarray(demand))
    )
    # Prefix property.
    selected = np.flatnonzero(mask)
    assert selected.size > 0
    assert np.array_equal(selected, np.arange(selected.size))
    # Coverage.
    assert cores[mask == 1].sum() >= demand_val
    # Minimality: without the last selected job, coverage fails.
    assert cores[mask == 1][:-1].sum() < demand_val


def test_select_zero_demand_selects_nothing():
    cores = np.array([4, 4, 4], np.float32)
    mask = preempt_select.select_victims(
        jnp.asarray(cores), jnp.asarray(np.array([0.0], np.float32))
    )
    assert np.asarray(mask).sum() == 0


def test_select_ignores_padding():
    cores = np.array([8, 0, 0, 8], np.float32)  # zeros = padding
    mask = np.asarray(
        preempt_select.select_victims(
            jnp.asarray(cores), jnp.asarray(np.array([16.0], np.float32))
        )
    )
    np.testing.assert_array_equal(mask, [1, 0, 0, 1])


# ---- fit -------------------------------------------------------------------


@hypothesis.given(
    m=st.integers(1, 600),
    n=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_fit_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    free = rng.integers(0, 64, size=m).astype(np.float32)
    reqs = rng.integers(1, 64, size=n).astype(np.float32)
    got = fit.fit_counts(jnp.asarray(free), jnp.asarray(reqs))
    want = ref.fit_counts_ref(free, reqs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fit_padding_requirement_counts_zero():
    free = np.full(16, 64.0, np.float32)
    reqs = np.array([1.0, 1e18], np.float32)
    got = np.asarray(fit.fit_counts(jnp.asarray(free), jnp.asarray(reqs)))
    np.testing.assert_array_equal(got, [16, 0])


def test_fit_busy_nodes_dont_count():
    free = np.array([0.0, 0.0, 32.0], np.float32)
    reqs = np.array([16.0], np.float32)
    got = np.asarray(fit.fit_counts(jnp.asarray(free), jnp.asarray(reqs)))
    np.testing.assert_array_equal(got, [1])


# ---- dtype robustness -------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_kernels_accept_other_dtypes(dtype):
    factors = np.ones((4, 3), dtype)
    weights = np.ones(3, dtype)
    got = priority.priority_scores(jnp.asarray(factors), jnp.asarray(weights))
    assert got.dtype == jnp.float32
    assert_allclose(np.asarray(got), np.full(4, 3.0))
