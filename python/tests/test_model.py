"""L2 model tests: composed sched_step semantics and the AOT shape contract."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def _padded_inputs():
    rng = np.random.default_rng(7)
    factors = np.zeros((model.JOBS, model.FACTORS), np.float32)
    factors[:10] = rng.normal(size=(10, model.FACTORS))
    spot = np.zeros(model.SPOTS, np.float32)
    spot[:5] = [256, 128, 512, 64, 64]
    demand = np.array([300.0], np.float32)
    free = np.zeros(model.NODES, np.float32)
    free[:19] = 32.0
    reqs = np.full(model.JOBS, 1e18, np.float32)
    reqs[:10] = rng.integers(1, 40, size=10)
    return factors, spot, demand, free, reqs


def test_sched_step_shapes_and_dtypes():
    factors, spot, demand, free, reqs = _padded_inputs()
    scores, mask, counts = model.sched_step(
        jnp.asarray(factors),
        model.WEIGHTS,
        jnp.asarray(spot),
        jnp.asarray(demand),
        jnp.asarray(free),
        jnp.asarray(reqs),
    )
    assert scores.shape == (model.JOBS,) and scores.dtype == jnp.float32
    assert mask.shape == (model.SPOTS,) and mask.dtype == jnp.int32
    assert counts.shape == (model.JOBS,) and counts.dtype == jnp.int32


def test_sched_step_matches_refs():
    factors, spot, demand, free, reqs = _padded_inputs()
    scores, mask, counts = model.sched_step(
        jnp.asarray(factors),
        model.WEIGHTS,
        jnp.asarray(spot),
        jnp.asarray(demand),
        jnp.asarray(free),
        jnp.asarray(reqs),
    )
    assert_allclose(
        np.asarray(scores),
        np.asarray(ref.priority_scores_ref(factors, np.asarray(model.WEIGHTS))),
        rtol=1e-5,
        atol=1e-4,
    )
    np.testing.assert_array_equal(
        np.asarray(mask), np.asarray(ref.select_victims_ref(spot, demand))
    )
    np.testing.assert_array_equal(
        np.asarray(counts), np.asarray(ref.fit_counts_ref(free, reqs))
    )
    # Semantic spot-check: demand 300 youngest-first over [256,128,...] takes
    # the first two jobs.
    np.testing.assert_array_equal(np.asarray(mask)[:5], [1, 1, 0, 0, 0])


def test_weights_match_rust_constants():
    # rust/src/sched/priority.rs WEIGHTS — keep in sync.
    np.testing.assert_array_equal(
        np.asarray(model.WEIGHTS),
        np.array([1000.0, 1.0, 0.1, 5.0, 10.0, -50.0, 0.0, 0.0], np.float32),
    )


def test_model_lowers_with_static_shapes():
    lowered = jax.jit(model.sched_step).lower(*model.example_args())
    text = str(lowered.compiler_ir("stablehlo"))
    assert f"{model.JOBS}x{model.FACTORS}" in text
