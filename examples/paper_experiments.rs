//! Regenerate every figure and table in the paper's evaluation section and
//! print paper-vs-measured shape checks.
//!
//! Run with: `cargo run --release --example paper_experiments [-- <id>]`

fn main() {
    let arg = std::env::args().nth(1);
    let ids: Vec<&str> = match arg.as_deref() {
        Some(id) => vec![spotcloud::experiments::ALL
            .iter()
            .copied()
            .find(|&x| x == id)
            .unwrap_or_else(|| {
                eprintln!(
                    "unknown experiment {id:?}; available: {}",
                    spotcloud::experiments::ALL.join(", ")
                );
                std::process::exit(2);
            })],
        None => spotcloud::experiments::ALL.to_vec(),
    };

    let mut all_ok = true;
    for id in ids {
        let report = spotcloud::experiments::run_by_id(id, 1).expect("known id");
        println!("{}", report.render());
        all_ok &= report.check();
    }
    if all_ok {
        println!("ALL PAPER-SHAPE CHECKS PASSED");
    } else {
        println!("SOME PAPER-SHAPE CHECKS FAILED");
        std::process::exit(1);
    }
}
