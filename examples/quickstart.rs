//! Quickstart: the paper's core phenomenon in ~60 lines.
//!
//! Builds the TX-2500 development cluster, fills it with a spot job, and
//! submits the same interactive job under three configurations:
//!
//! 1. baseline (idle cluster),
//! 2. scheduler-automatic QoS preemption (what the paper rejects),
//! 3. the cron-agent approach (the paper's contribution),
//! 4. the same measurement end-to-end over TCP with the typed v2 client
//!    (batched SUBMIT + WAIT).
//!
//! Run with: `cargo run --release --example quickstart`

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{Client, Daemon, DaemonConfig, Server, SubmitSpec};
use spotcloud::job::{JobSpec, JobType, QosClass, UserId};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::{Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};
use std::sync::Arc;

fn main() {
    println!("SpotCloud quickstart — interactive launch latency, three ways\n");

    // 1. Baseline: idle cluster, no spot jobs.
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual);
    let mut sched = Scheduler::new(topology::tx2500(), cfg);
    let job = sched.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    sched.run_until_dispatched(&[job], SimTime::from_secs(60));
    let baseline = sched.log().measure(&[job]).unwrap().total_secs;
    println!("baseline (idle cluster)        : {baseline:.3} s");

    // 2. Automatic scheduler preemption: the cluster is full of spot work
    //    and the scheduler preempts inside its allocation path.
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Requeue,
        });
    let mut sched = Scheduler::new(topology::tx2500(), cfg);
    let spot = sched.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
    sched.run_until_dispatched(&[spot], SimTime::from_secs(60));
    let job = sched.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    sched.run_until_dispatched(&[job], SimTime::from_secs(3600));
    let auto = sched.log().measure(&[job]).unwrap().total_secs;
    println!("scheduler auto-preemption      : {auto:.3} s   ({:.0}x baseline)", auto / baseline);

    // 3. Cron agent: spot jobs are capped below a 5-node idle reserve and a
    //    privileged agent requeues them LIFO, outside the submit path.
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(5 * 32)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let mut sched = Scheduler::new(topology::tx2500(), cfg);
    // Several spot jobs (as the paper runs them) so the agent's LIFO
    // requeues free only as much as the reserve needs. 4 x 96 cores =
    // 12 whole nodes — everything the agent's ceiling allows.
    let spots = sched.submit_burst(spotcloud::workload::spot_fill(UserId(9), 384, 4));
    sched.run_until_dispatched(&spots, SimTime::from_secs(300));
    let job = sched.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160));
    sched.run_until_dispatched(&[job], SimTime::from_secs(60));
    let cron = sched.log().measure(&[job]).unwrap().total_secs;
    println!(
        "cron agent (spot-loaded cluster): {cron:.3} s   ({:.1}x baseline) — \"best of both worlds\"",
        cron / baseline
    );
    // Give the agent a couple of intervals to restore the idle reserve.
    sched.run_for(SimTime::from_secs(150));
    println!(
        "\nutilization with spot jobs: {:.0}%  ({} idle nodes restored for the next interactive job)",
        sched.cluster().utilization() * 100.0,
        sched.cluster().idle_node_count()
    );

    // 4. The same phenomenon measured end-to-end: daemon over TCP, typed v2
    //    client, spot backlog loaded with one batched SUBMIT, and WAIT
    //    returning the interactive job's virtual scheduling latency.
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(5 * 32)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let server_thread = std::thread::spawn(move || server.serve());

    let mut c = Client::connect_v2(&addr).expect("connect");
    let spots = c
        .submit(
            &SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 96, 9)
                .with_run_secs(86_400.0)
                .with_count(4), // 4 x 96 tasks in ONE RPC
        )
        .expect("spot backlog");
    let spot_ids: Vec<u64> = spots.ids().collect();
    c.wait(&spot_ids, 20.0).expect("spot fill");
    let inter = c
        .submit(&SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 160, 1).with_run_secs(120.0))
        .expect("interactive");
    let ids: Vec<u64> = inter.ids().collect();
    let w = c.wait(&ids, 20.0).expect("wait");
    println!(
        "\nover TCP (typed v2 client)     : {:.3} s virtual launch latency on a spot-loaded \
         cluster ({})",
        w.latency_ns as f64 / 1e9,
        w
    );
    let _ = c.shutdown();
    server_thread.join().ok();
    pacer.join().ok();
}
