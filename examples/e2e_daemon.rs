//! END-TO-END DRIVER: the full system on a real (simulated-time, real
//! threads, real TCP) workload.
//!
//! Composes every layer:
//!   * L1/L2: the AOT-compiled XLA scheduling decision kernel on the
//!     scheduler's priority path (falls back to native scoring when
//!     `make artifacts` hasn't run),
//!   * L3: the coordinator daemon — threaded TCP service over the
//!     `slurmlite` scheduler with the cron agent managing spot jobs.
//!
//! The driver starts the daemon on a loopback port, loads a spot backlog,
//! replays a Poisson interactive workload through real TCP clients, and
//! reports scheduling latency (virtual), request latency (wall), throughput,
//! and utilization. Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with: `cargo run --release --example e2e_daemon`

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{client::Client, Daemon, DaemonConfig, Server};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use spotcloud::util::rng::Xoshiro256;
use std::sync::Arc;
use std::time::{Duration, Instant};

const RESERVE_NODES: u32 = 8;
const INTERACTIVE_SUBMISSIONS: usize = 200;
const SPEEDUP: f64 = 600.0; // 10 virtual minutes per wall second

fn main() {
    println!("SpotCloud end-to-end driver — TX-Green reservation (64 nodes x 64 cores)\n");

    // --- assemble the stack -------------------------------------------------
    let mut sched_cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(RESERVE_NODES * 64)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig {
                reserve_nodes: RESERVE_NODES,
            },
        });
    let scorer_name;
    match spotcloud::runtime::SchedAccel::load_default() {
        Some(accel) => {
            scorer_name = "xla-accel (AOT sched_step.hlo.txt via PJRT)";
            sched_cfg = sched_cfg.with_scorer(Arc::new(accel));
        }
        None => {
            scorer_name = "native (run `make artifacts` for the XLA path)";
        }
    }
    println!("priority scorer: {scorer_name}");

    let daemon = Daemon::new(
        topology::txgreen_reservation(),
        sched_cfg,
        DaemonConfig {
            speedup: SPEEDUP,
            pacer_tick_ms: 2,
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 4).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let server_daemon = Arc::clone(&daemon);
    let server_thread = std::thread::spawn(move || {
        let _ = &server_daemon; // keep alive
        server.serve();
    });
    println!("daemon listening on {addr} (speedup {SPEEDUP}x)\n");

    // --- spot backlog --------------------------------------------------------
    let mut c = Client::connect(&addr).expect("connect");
    for _ in 0..10 {
        let resp = c
            .request("SUBMIT spot triple 448 900 86400") // 7 nodes each
            .expect("submit spot");
        assert!(resp.starts_with("OK"), "{resp}");
    }
    std::thread::sleep(Duration::from_millis(500)); // let spot land
    println!("spot backlog loaded: {}", c.request("UTIL").unwrap());

    // --- interactive workload over TCP --------------------------------------
    let mut rng = Xoshiro256::new(2026);
    let t0 = Instant::now();
    let mut submitted = 0usize;
    for i in 0..INTERACTIVE_SUBMISSIONS {
        // Poisson arrivals: mean 30 virtual seconds apart = 50ms wall at 600x.
        let wall_gap = rng.exponential(1.0 / 30.0) / SPEEDUP;
        std::thread::sleep(Duration::from_secs_f64(wall_gap.min(0.5)));
        let tasks = *rng.choose(&[64u32, 128, 256, 512]);
        let ty = *rng.choose(&["triple", "triple", "array"]); // SuperCloud mix
        let user = 1 + (i % 8);
        let resp = c
            .request(&format!("SUBMIT normal {ty} {tasks} {user} 120"))
            .expect("submit");
        assert!(resp.starts_with("OK"), "{resp}");
        submitted += 1;
    }
    let submit_wall = t0.elapsed();

    // --- drain ---------------------------------------------------------------
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let done = daemon.metrics.sched_latency().count() as usize;
        if done >= submitted || Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }

    // --- report ----------------------------------------------------------------
    let sched_hist = daemon.metrics.sched_latency();
    let req_hist = daemon.metrics.request_latency();
    let stats = c.request("STATS").unwrap();
    let util = c.request("UTIL").unwrap();
    println!("\n===== END-TO-END REPORT =====");
    println!("interactive submissions     : {submitted} (over {:.1}s wall)", submit_wall.as_secs_f64());
    println!(
        "submission throughput       : {:.0} requests/s wall",
        submitted as f64 / submit_wall.as_secs_f64()
    );
    println!("dispatched                  : {}", sched_hist.count());
    println!("virtual sched latency       : {}", sched_hist.summary_ns());
    println!("wall request latency        : {}", req_hist.summary_ns());
    println!("final cluster state         : {util}");
    println!("scheduler stats             : {stats}");

    let p50_virtual_secs = sched_hist.p50() as f64 / 1e9;
    println!(
        "\nheadline: interactive p50 scheduling latency {p50_virtual_secs:.2}s on a spot-saturated \
         cluster (paper: comparable to baseline)"
    );

    // --- shutdown -------------------------------------------------------------
    let _ = c.request("SHUTDOWN");
    server_thread.join().ok();
    pacer.join().ok();

    assert!(sched_hist.count() > 0, "no jobs dispatched");
    assert!(
        p50_virtual_secs < 60.0,
        "p50 {p50_virtual_secs}s should be far below the cron interval"
    );
    println!("\ne2e driver completed OK");
}
