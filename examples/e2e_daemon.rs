//! END-TO-END DRIVER: the full system on a real (simulated-time, real
//! threads, real TCP) workload.
//!
//! Composes every layer:
//!   * L1/L2: the AOT-compiled XLA scheduling decision kernel on the
//!     scheduler's priority path (falls back to native scoring when
//!     `make artifacts` hasn't run),
//!   * L3: the coordinator daemon — threaded TCP service over the
//!     `slurmlite` scheduler with the cron agent managing spot jobs,
//!     spoken through the typed v2 protocol client.
//!
//! The driver starts the daemon on a loopback port, loads a spot backlog
//! with one batched SUBMIT, replays a Poisson interactive workload through a
//! real TCP client, measures one burst's launch latency remotely with WAIT,
//! and reports scheduling latency (virtual), request latency (wall),
//! throughput, and utilization. Results are recorded in EXPERIMENTS.md
//! §End-to-end.
//!
//! Run with: `cargo run --release --example e2e_daemon`

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{Client, Daemon, DaemonConfig, Server, SubmitSpec};
use spotcloud::job::{JobType, QosClass};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use spotcloud::util::rng::Xoshiro256;
use std::sync::Arc;
use std::time::{Duration, Instant};

const RESERVE_NODES: u32 = 8;
const INTERACTIVE_SUBMISSIONS: usize = 200;
const SPEEDUP: f64 = 600.0; // 10 virtual minutes per wall second

fn main() {
    println!("SpotCloud end-to-end driver — TX-Green reservation (64 nodes x 64 cores)\n");

    // --- assemble the stack -------------------------------------------------
    let mut sched_cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(RESERVE_NODES * 64)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig {
                reserve_nodes: RESERVE_NODES,
            },
        });
    let scorer_name;
    match spotcloud::runtime::SchedAccel::load_default() {
        Some(accel) => {
            scorer_name = "xla-accel (AOT sched_step.hlo.txt via PJRT)";
            sched_cfg = sched_cfg.with_scorer(Arc::new(accel));
        }
        None => {
            scorer_name = "native (run `make artifacts` for the XLA path)";
        }
    }
    println!("priority scorer: {scorer_name}");

    let daemon = Daemon::new(
        topology::txgreen_reservation(),
        sched_cfg,
        DaemonConfig {
            speedup: SPEEDUP,
            pacer_tick_ms: 2,
            ..DaemonConfig::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 4).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let server_daemon = Arc::clone(&daemon);
    let server_thread = std::thread::spawn(move || {
        let _ = &server_daemon; // keep alive
        server.serve();
    });
    println!("daemon listening on {addr} (speedup {SPEEDUP}x, protocol v2)\n");

    // --- spot backlog: one batched RPC --------------------------------------
    let mut c = Client::connect_v2(&addr).expect("connect");
    let spot_ack = c
        .submit(
            &SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 448, 900) // 7 nodes each
                .with_run_secs(86_400.0)
                .with_count(10),
        )
        .expect("submit spot backlog");
    std::thread::sleep(Duration::from_millis(500)); // let spot land
    println!(
        "spot backlog loaded in one RPC ({spot_ack}): {}",
        c.util().expect("util")
    );

    // --- interactive workload over TCP --------------------------------------
    let mut rng = Xoshiro256::new(2026);
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut last_burst = Vec::new();
    for i in 0..INTERACTIVE_SUBMISSIONS {
        // Poisson arrivals: mean 30 virtual seconds apart = 50ms wall at 600x.
        let wall_gap = rng.exponential(1.0 / 30.0) / SPEEDUP;
        std::thread::sleep(Duration::from_secs_f64(wall_gap.min(0.5)));
        let tasks = *rng.choose(&[64u32, 128, 256, 512]);
        let ty = *rng.choose(&[JobType::TripleMode, JobType::TripleMode, JobType::Array]); // SuperCloud mix
        let user = 1 + (i as u32 % 8);
        let ack = c
            .submit(
                &SubmitSpec::new(QosClass::Normal, ty, tasks, user).with_run_secs(120.0),
            )
            .expect("submit");
        last_burst = ack.ids().collect();
        submitted += 1;
    }
    let submit_wall = t0.elapsed();

    // --- the paper's metric, measured remotely -------------------------------
    let final_wait = c.wait(&last_burst, 30.0).expect("wait");
    println!(
        "remote WAIT on the last submission: {final_wait} \
         (virtual latency via the daemon's event log)"
    );

    // --- drain ---------------------------------------------------------------
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let done = daemon.metrics.sched_latency().count() as usize;
        if done >= submitted || Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }

    // --- report ----------------------------------------------------------------
    let sched_hist = daemon.metrics.sched_latency();
    let req_hist = daemon.metrics.request_latency();
    let stats = c.stats().expect("stats");
    let util = c.util().expect("util");
    println!("\n===== END-TO-END REPORT =====");
    println!("interactive submissions     : {submitted} (over {:.1}s wall)", submit_wall.as_secs_f64());
    println!(
        "submission throughput       : {:.0} requests/s wall",
        submitted as f64 / submit_wall.as_secs_f64()
    );
    println!("dispatched                  : {}", sched_hist.count());
    println!("virtual sched latency       : {}", sched_hist.summary_ns());
    println!("wall request latency        : {}", req_hist.summary_ns());
    println!("final cluster state         : {util}");
    println!(
        "scheduler stats             : dispatches={} preemptions={} requeues={} cron_passes={} scorer={}",
        stats.dispatches, stats.preemptions, stats.requeues, stats.cron_passes, stats.scorer
    );
    println!(
        "requests by command         : {}",
        stats
            .commands
            .iter()
            .filter(|&(_, &n)| n > 0)
            .map(|(cmd, n)| format!("{cmd}={n}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    let p50_virtual_secs = sched_hist.p50() as f64 / 1e9;
    println!(
        "\nheadline: interactive p50 scheduling latency {p50_virtual_secs:.2}s on a spot-saturated \
         cluster (paper: comparable to baseline)"
    );

    // --- shutdown -------------------------------------------------------------
    let _ = c.shutdown();
    server_thread.join().ok();
    pacer.join().ok();

    assert!(sched_hist.count() > 0, "no jobs dispatched");
    assert!(
        p50_virtual_secs < 60.0,
        "p50 {p50_virtual_secs}s should be far below the cron interval"
    );
    assert!(!final_wait.timed_out, "remote WAIT must observe the dispatch");
    println!("\ne2e driver completed OK");
}
