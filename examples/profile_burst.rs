//! Profiling driver for the heaviest simulation workload (the 4096-job
//! individual burst from Fig 2c), used by the perf pass (EXPERIMENTS.md):
//!
//! ```text
//! cargo build --release --example profile_burst
//! perf record -g --call-graph dwarf ./target/release/examples/profile_burst
//! perf report --stdio --no-children
//! ```
use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::job::{JobSpec, JobType, UserId};
use spotcloud::sched::{Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};
fn main() {
    for _ in 0..200 {
        let mut s = Scheduler::new(
            topology::txgreen_reservation(),
            SchedulerConfig::baseline(SchedCosts::production(), PartitionLayout::Dual),
        );
        let ids = s.submit_burst(
            (0..4096).map(|_| JobSpec::interactive(UserId(1), JobType::Individual, 1)).collect(),
        );
        s.run_until_dispatched(&ids, SimTime::from_secs(7200));
        std::hint::black_box(s.stats().dispatches);
    }
}
