//! Spot + cron-agent demo: a day in the life of the cluster.
//!
//! Replays a Poisson interactive workload over a saturated spot backlog with
//! the cron agent keeping the idle reserve, and prints a timeline of agent
//! actions plus the utilization/latency report. Also runs the no-spot
//! baseline for comparison — the paper's utilization argument.
//!
//! Run with: `cargo run --release --example spot_cron_demo`

use spotcloud::sched::LogKind;
use spotcloud::workload::simulate_mixed;

fn main() {
    println!("SpotCloud — spot jobs + cron agent, 4 virtual hours on TX-2500\n");

    let with_spot = simulate_mixed(42, 4, 120, 5, true);
    let without = simulate_mixed(42, 4, 120, 5, false);

    println!("--- WITHOUT spot jobs (interactive only) ---");
    print!("{without}");
    println!();
    println!("--- WITH spot jobs + cron agent ---");
    print!("{with_spot}");

    let delta = (with_spot.avg_utilization - without.avg_utilization) * 100.0;
    println!(
        "\nspot jobs add {delta:.0} utilization points while interactive p50 stays at {:.2}s \
         (vs {:.2}s without spot)",
        with_spot.sched_latency.as_ref().map(|s| s.p50).unwrap_or(0.0),
        without.sched_latency.as_ref().map(|s| s.p50).unwrap_or(0.0),
    );

    // A close-up of the agent's preemption behavior (LIFO order).
    println!("\n--- agent close-up: LIFO requeues on a loaded cluster ---");
    use spotcloud::cluster::{topology, PartitionLayout};
    use spotcloud::job::{JobSpec, JobType, UserId};
    use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
    use spotcloud::sched::{Scheduler, SchedulerConfig};
    use spotcloud::sim::{SchedCosts, SimTime};

    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(5 * 32)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let mut sched = Scheduler::new(topology::tx2500(), cfg);
    let mut spots = Vec::new();
    for i in 0..4 {
        sched.run_for(SimTime::from_secs(30)); // stagger ages
        let s = sched.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 96));
        sched.run_until_dispatched(&[s], SimTime::from_secs(120));
        println!("t={:>8}  spot job {} started (3 nodes)", format!("{}", sched.now()), i + 1);
        spots.push(s);
    }
    let j = sched.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160));
    sched.run_until_dispatched(&[j], SimTime::from_secs(60));
    println!(
        "t={:>8}  interactive job landed on the reserve in {:.2}s",
        format!("{}", sched.now()),
        sched.log().measure(&[j]).unwrap().total_secs
    );
    sched.run_for(SimTime::from_secs(180));
    for e in sched.log().entries() {
        if e.kind == LogKind::CronPreempted {
            println!("t={:>8}  cron agent requeued {} (youngest-first)", format!("{}", e.time), e.job);
        }
    }
    println!(
        "idle nodes restored: {} (reserve = 5) — oldest spot jobs kept running",
        sched.cluster().idle_node_count()
    );

    // The same close-up, measured remotely: daemon + typed v2 client, with
    // WAIT reporting the interactive job's virtual launch latency over TCP.
    use spotcloud::coordinator::{Client, Daemon, DaemonConfig, Server, SqueueFilter, SubmitSpec};
    use spotcloud::job::QosClass;
    use std::sync::Arc;

    println!("\n--- remote close-up: the same measurement over the typed v2 protocol ---");
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(5 * 32)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let server_thread = std::thread::spawn(move || server.serve());

    let mut c = Client::connect_v2(&addr).expect("connect");
    let spots = c
        .submit(
            &SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 96, 9)
                .with_run_secs(86_400.0)
                .with_count(4),
        )
        .expect("spot backlog");
    c.wait(&spots.ids().collect::<Vec<_>>(), 20.0).expect("spot fill");
    let inter = c
        .submit(&SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 160, 1).with_run_secs(120.0))
        .expect("interactive");
    let w = c
        .wait(&inter.ids().collect::<Vec<_>>(), 20.0)
        .expect("wait");
    println!("interactive launch latency over TCP: {w}");
    let spot_rows = c
        .squeue(&SqueueFilter {
            qos: Some(QosClass::Spot),
            ..Default::default()
        })
        .expect("squeue");
    println!("spot jobs still active (filtered SQUEUE): {}", spot_rows.len());
    let _ = c.shutdown();
    server_thread.join().ok();
    pacer.join().ok();
}
