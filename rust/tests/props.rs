//! Property-based invariant tests over the whole scheduler stack, using the
//! in-crate `testkit::prop` harness (proptest is unavailable offline).

use spotcloud::cluster::{AllocRequest, Cluster, PartitionLayout};
use spotcloud::job::{JobId, JobSpec, JobState, JobType, UserId};
use spotcloud::preempt::lifo::{self, Demand, Order, Victim};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::{Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};
use spotcloud::testkit::prop::Prop;

#[test]
fn prop_cluster_never_oversubscribes() {
    Prop::new("cluster alloc/release keeps invariants").cases(100).run(|g| {
        let nodes = g.u64(1, 32) as u32;
        let cores = g.u64(1, 64) as u32;
        let mut cluster = Cluster::homogeneous(nodes, cores);
        let mut live: Vec<JobId> = Vec::new();
        let mut next = 1u64;
        for _ in 0..g.usize(1, 60) {
            if g.bool(0.6) || live.is_empty() {
                let req = if g.bool(0.5) {
                    AllocRequest::Cores(g.u64(1, (nodes * cores) as u64 * 2) as u32)
                } else {
                    AllocRequest::WholeNodes(g.u64(1, nodes as u64 * 2) as u32)
                };
                let id = JobId(next);
                next += 1;
                if cluster.allocate(id, req).is_some() {
                    live.push(id);
                }
            } else {
                let idx = g.usize(0, live.len() - 1);
                let id = live.swap_remove(idx);
                assert!(cluster.release(id).is_some());
            }
            cluster.check_invariants().expect("cluster invariants");
            assert!(cluster.idle_cores() <= cluster.total_cores());
        }
        // Release everything: back to fully idle.
        for id in live {
            cluster.release(id).unwrap();
        }
        assert_eq!(cluster.idle_cores(), cluster.total_cores());
    });
}

#[test]
fn prop_lifo_selection_minimal_and_covering() {
    Prop::new("victim selection covers demand minimally").cases(150).run(|g| {
        let victims: Vec<Victim> = (0..g.usize(1, 40))
            .map(|i| Victim {
                job: JobId(i as u64 + 1),
                queue_time: SimTime(g.u64(0, 1_000_000_000)),
                cores: g.u64(1, 512) as u32,
                whole_nodes: g.u64(0, 8) as u32,
            })
            .collect();
        let total: u64 = victims.iter().map(|v| v.cores as u64).sum();
        let demand = g.u64(1, total);
        let order = if g.bool(0.5) {
            Order::YoungestFirst
        } else {
            Order::OldestFirst
        };
        let selected = lifo::select_victims(&victims, Demand::Cores(demand as u32), order)
            .expect("demand <= total must be satisfiable");
        let freed: u64 = selected
            .iter()
            .map(|id| victims.iter().find(|v| v.job == *id).unwrap().cores as u64)
            .sum();
        assert!(freed >= demand, "freed {freed} < demand {demand}");
        // Minimality: dropping the last victim breaks coverage.
        let without_last: u64 = selected[..selected.len() - 1]
            .iter()
            .map(|id| victims.iter().find(|v| v.job == *id).unwrap().cores as u64)
            .sum();
        assert!(without_last < demand, "selection not minimal");
        // Order property: selections follow the requested order strictly.
        let times: Vec<SimTime> = selected
            .iter()
            .map(|id| victims.iter().find(|v| v.job == *id).unwrap().queue_time)
            .collect();
        match order {
            Order::YoungestFirst => assert!(times.windows(2).all(|w| w[0] >= w[1])),
            Order::OldestFirst => assert!(times.windows(2).all(|w| w[0] <= w[1])),
        }
    });
}

#[test]
fn prop_fallback_select_matches_lifo_semantics() {
    Prop::new("rust fallback mask == minimal prefix").cases(150).run(|g| {
        let cores: Vec<f32> = (0..g.usize(1, 100))
            .map(|_| if g.bool(0.1) { 0.0 } else { g.u64(1, 512) as f32 })
            .collect();
        let total: f32 = cores.iter().sum();
        let demand = g.f64(0.0, (total as f64) * 1.2) as f32;
        let mask = spotcloud::runtime::fallback::select_victims(&cores, demand);
        // Mask covers demand if satisfiable, is a prefix over non-zero
        // entries, and is minimal.
        let freed: f32 = cores
            .iter()
            .zip(&mask)
            .filter(|(_, &m)| m)
            .map(|(&c, _)| c)
            .sum();
        if demand <= total && demand > 0.0 {
            assert!(freed >= demand, "freed {freed} < demand {demand}");
        }
        // Prefix over nonzero entries: once a nonzero entry is unselected,
        // no later entry is selected.
        let mut blocked = false;
        for (&c, &m) in cores.iter().zip(&mask) {
            if c > 0.0 {
                if blocked {
                    assert!(!m, "non-prefix selection");
                }
                if !m {
                    blocked = true;
                }
            } else {
                assert!(!m, "padding selected");
            }
        }
    });
}

#[test]
fn prop_scheduler_invariants_under_random_mixed_load() {
    Prop::new("scheduler invariants under random workloads").cases(25).run(|g| {
        let layout = if g.bool(0.5) {
            PartitionLayout::Single
        } else {
            PartitionLayout::Dual
        };
        let approach = match g.usize(0, 2) {
            0 => PreemptApproach::None,
            1 => PreemptApproach::AutoScheduler {
                mode: if g.bool(0.5) {
                    PreemptMode::Requeue
                } else {
                    PreemptMode::Cancel
                },
            },
            _ => PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig {
                    reserve_nodes: g.u64(1, 8) as u32,
                },
            },
        };
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), layout)
            .with_user_limit(g.u64(64, 608) as u32)
            .with_phase_seed(g.u64(0, u64::MAX / 2))
            .with_approach(approach);
        let mut sched = Scheduler::new(spotcloud::cluster::topology::tx2500(), cfg);

        for _ in 0..g.usize(1, 25) {
            let user = UserId(g.u64(1, 6) as u32);
            let ty = *g.pick(&[JobType::Individual, JobType::Array, JobType::TripleMode]);
            let tasks = g.u64(1, 608) as u32;
            let run = SimTime::from_secs(g.u64(10, 5_000));
            let spec = if g.bool(0.4) {
                JobSpec::spot(user, ty, tasks).with_run_time(run)
            } else {
                JobSpec::interactive(user, ty, tasks).with_run_time(run)
            };
            sched.submit(spec);
            sched.run_for(SimTime::from_secs(g.u64(1, 300)));
            sched.check_invariants().expect("scheduler invariants");
        }
        // Drain a long time: everything terminal or pending, never stuck in
        // transient states.
        sched.run_for(SimTime::from_secs(48 * 3600));
        sched.check_invariants().expect("scheduler invariants after drain");
        assert!(
            sched.jobs_in_state(JobState::Requeued).is_empty(),
            "requeued jobs must re-enter the queue"
        );
    });
}

#[test]
fn prop_event_log_times_monotone_per_kind() {
    Prop::new("dispatch happens after recognition").cases(20).run(|g| {
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual);
        let mut sched = Scheduler::new(spotcloud::cluster::topology::tx2500(), cfg);
        let ids: Vec<JobId> = (0..g.usize(1, 30))
            .map(|_| {
                sched.submit(JobSpec::interactive(
                    UserId(1),
                    JobType::Array,
                    g.u64(1, 64) as u32,
                ))
            })
            .collect();
        sched.run_for(SimTime::from_secs(3600));
        for id in ids {
            let rec = sched.log().first(id, spotcloud::sched::LogKind::Recognized);
            let dis = sched.log().last(id, spotcloud::sched::LogKind::DispatchDone);
            if let (Some(r), Some(d)) = (rec, dis) {
                assert!(d >= r, "{id}: dispatched before recognized");
            }
        }
    });
}
