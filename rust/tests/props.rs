//! Property-based invariant tests over the whole scheduler stack, using the
//! in-crate `testkit::prop` harness (proptest is unavailable offline).

use spotcloud::cluster::{AllocRequest, Cluster, PartitionLayout};
use spotcloud::coordinator::api::{ErrorCode, ProtocolVersion, Request, SqueueFilter, SubmitSpec};
use spotcloud::coordinator::codec;
use spotcloud::coordinator::manifest::{EntryAck, EntryReject, Manifest, ManifestAck, ManifestEntry};
use spotcloud::coordinator::{ApiError, ResumeTarget};
use spotcloud::job::{JobId, JobSpec, JobState, JobType, QosClass, UserId};
use spotcloud::preempt::lifo::{self, Demand, Order, Victim};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::{Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};
use spotcloud::testkit::prop::{Gen, Prop};

#[test]
fn prop_cluster_never_oversubscribes() {
    Prop::new("cluster alloc/release keeps invariants").cases(100).run(|g| {
        let nodes = g.u64(1, 32) as u32;
        let cores = g.u64(1, 64) as u32;
        let mut cluster = Cluster::homogeneous(nodes, cores);
        let mut live: Vec<JobId> = Vec::new();
        let mut next = 1u64;
        for _ in 0..g.usize(1, 60) {
            if g.bool(0.6) || live.is_empty() {
                let req = if g.bool(0.5) {
                    AllocRequest::Cores(g.u64(1, (nodes * cores) as u64 * 2) as u32)
                } else {
                    AllocRequest::WholeNodes(g.u64(1, nodes as u64 * 2) as u32)
                };
                let id = JobId(next);
                next += 1;
                if cluster.allocate(id, req).is_some() {
                    live.push(id);
                }
            } else {
                let idx = g.usize(0, live.len() - 1);
                let id = live.swap_remove(idx);
                assert!(cluster.release(id).is_some());
            }
            cluster.check_invariants().expect("cluster invariants");
            assert!(cluster.idle_cores() <= cluster.total_cores());
        }
        // Release everything: back to fully idle.
        for id in live {
            cluster.release(id).unwrap();
        }
        assert_eq!(cluster.idle_cores(), cluster.total_cores());
    });
}

#[test]
fn prop_lifo_selection_minimal_and_covering() {
    Prop::new("victim selection covers demand minimally").cases(150).run(|g| {
        let victims: Vec<Victim> = (0..g.usize(1, 40))
            .map(|i| Victim {
                job: JobId(i as u64 + 1),
                queue_time: SimTime(g.u64(0, 1_000_000_000)),
                cores: g.u64(1, 512) as u32,
                whole_nodes: g.u64(0, 8) as u32,
            })
            .collect();
        let total: u64 = victims.iter().map(|v| v.cores as u64).sum();
        let demand = g.u64(1, total);
        let order = if g.bool(0.5) {
            Order::YoungestFirst
        } else {
            Order::OldestFirst
        };
        let selected = lifo::select_victims(&victims, Demand::Cores(demand as u32), order)
            .expect("demand <= total must be satisfiable");
        let freed: u64 = selected
            .iter()
            .map(|id| victims.iter().find(|v| v.job == *id).unwrap().cores as u64)
            .sum();
        assert!(freed >= demand, "freed {freed} < demand {demand}");
        // Minimality: dropping the last victim breaks coverage.
        let without_last: u64 = selected[..selected.len() - 1]
            .iter()
            .map(|id| victims.iter().find(|v| v.job == *id).unwrap().cores as u64)
            .sum();
        assert!(without_last < demand, "selection not minimal");
        // Order property: selections follow the requested order strictly.
        let times: Vec<SimTime> = selected
            .iter()
            .map(|id| victims.iter().find(|v| v.job == *id).unwrap().queue_time)
            .collect();
        match order {
            Order::YoungestFirst => assert!(times.windows(2).all(|w| w[0] >= w[1])),
            Order::OldestFirst => assert!(times.windows(2).all(|w| w[0] <= w[1])),
        }
    });
}

#[test]
fn prop_fallback_select_matches_lifo_semantics() {
    Prop::new("rust fallback mask == minimal prefix").cases(150).run(|g| {
        let cores: Vec<f32> = (0..g.usize(1, 100))
            .map(|_| if g.bool(0.1) { 0.0 } else { g.u64(1, 512) as f32 })
            .collect();
        let total: f32 = cores.iter().sum();
        let demand = g.f64(0.0, (total as f64) * 1.2) as f32;
        let mask = spotcloud::runtime::fallback::select_victims(&cores, demand);
        // Mask covers demand if satisfiable, is a prefix over non-zero
        // entries, and is minimal.
        let freed: f32 = cores
            .iter()
            .zip(&mask)
            .filter(|(_, &m)| m)
            .map(|(&c, _)| c)
            .sum();
        if demand <= total && demand > 0.0 {
            assert!(freed >= demand, "freed {freed} < demand {demand}");
        }
        // Prefix over nonzero entries: once a nonzero entry is unselected,
        // no later entry is selected.
        let mut blocked = false;
        for (&c, &m) in cores.iter().zip(&mask) {
            if c > 0.0 {
                if blocked {
                    assert!(!m, "non-prefix selection");
                }
                if !m {
                    blocked = true;
                }
            } else {
                assert!(!m, "padding selected");
            }
        }
    });
}

#[test]
fn prop_scheduler_invariants_under_random_mixed_load() {
    Prop::new("scheduler invariants under random workloads").cases(25).run(|g| {
        let layout = if g.bool(0.5) {
            PartitionLayout::Single
        } else {
            PartitionLayout::Dual
        };
        let approach = match g.usize(0, 2) {
            0 => PreemptApproach::None,
            1 => PreemptApproach::AutoScheduler {
                mode: if g.bool(0.5) {
                    PreemptMode::Requeue
                } else {
                    PreemptMode::Cancel
                },
            },
            _ => PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig {
                    reserve_nodes: g.u64(1, 8) as u32,
                },
            },
        };
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), layout)
            .with_user_limit(g.u64(64, 608) as u32)
            .with_phase_seed(g.u64(0, u64::MAX / 2))
            .with_approach(approach);
        let mut sched = Scheduler::new(spotcloud::cluster::topology::tx2500(), cfg);

        for _ in 0..g.usize(1, 25) {
            let user = UserId(g.u64(1, 6) as u32);
            let ty = *g.pick(&[JobType::Individual, JobType::Array, JobType::TripleMode]);
            let tasks = g.u64(1, 608) as u32;
            let run = SimTime::from_secs(g.u64(10, 5_000));
            let spec = if g.bool(0.4) {
                JobSpec::spot(user, ty, tasks).with_run_time(run)
            } else {
                JobSpec::interactive(user, ty, tasks).with_run_time(run)
            };
            sched.submit(spec);
            sched.run_for(SimTime::from_secs(g.u64(1, 300)));
            sched.check_invariants().expect("scheduler invariants");
        }
        // Drain a long time: everything terminal or pending, never stuck in
        // transient states.
        sched.run_for(SimTime::from_secs(48 * 3600));
        sched.check_invariants().expect("scheduler invariants after drain");
        assert!(
            sched.jobs_in_state(JobState::Requeued).is_empty(),
            "requeued jobs must re-enter the queue"
        );
    });
}

// ---- v3 binary wire ⇄ typed ⇄ v2 text equivalence --------------------------

const TAG_CHARS: &[char] = &[
    'a', 'b', 'k', 'z', 'A', 'Z', '0', '5', '9', '.', '_', ':', '/', '-',
];

fn gen_tag(g: &mut Gen) -> String {
    (0..g.usize(1, 16)).map(|_| *g.pick(TAG_CHARS)).collect()
}

fn gen_entry(g: &mut Gen) -> ManifestEntry {
    let qos = if g.bool(0.5) {
        QosClass::Normal
    } else {
        QosClass::Spot
    };
    let job_type = *g.pick(&[JobType::Individual, JobType::Array, JobType::TripleMode]);
    let tasks = g.u64(1, 1_000_000) as u32;
    let user = g.u64(0, u32::MAX as u64) as u32;
    let mut e = ManifestEntry::new(qos, job_type, tasks, user)
        .with_run_secs(g.f64(0.0, 1.0e7))
        .with_count(g.u64(1, 10_000) as u32)
        .with_cores_per_task(g.u64(1, 64) as u32);
    if g.bool(0.4) {
        e = e.with_tag(gen_tag(g));
    }
    e
}

#[test]
fn prop_v3_manifest_codec_matches_v2_text() {
    Prop::new("v3 binary manifest codec == v2 text, typed").cases(40).run(|g| {
        let m = Manifest {
            entries: (0..g.usize(1, 40)).map(|_| gen_entry(g)).collect(),
        };

        // Binary round trip is exact (run_secs carries raw f64 bits).
        let payload = codec::render_msubmit_v3(&m);
        let from_v3 = codec::parse_msubmit_v3(&payload).expect("v3 binary parse");
        assert_eq!(from_v3, m);

        // The v2 text line parses to the same typed manifest (Display
        // renders the shortest exactly-round-tripping f64), and the text
        // grammar is identical across v2 / v2.1 / v3 — a v3 TEXT_REQ
        // frame carries byte-for-byte v2 text.
        let line = codec::render_request(&Request::MSubmit(m.clone()), ProtocolVersion::V2);
        for v in [ProtocolVersion::V2, ProtocolVersion::V21, ProtocolVersion::V3] {
            assert_eq!(
                codec::render_request(&Request::MSubmit(m.clone()), v),
                line,
                "MSUBMIT text must not vary by dialect"
            );
            match codec::parse_request(&line, v).expect("text parse") {
                Request::MSubmit(from_text) => assert_eq!(from_text, m, "{v:?}"),
                other => panic!("MSUBMIT parsed as {other:?}"),
            }
        }
    });
}

#[test]
fn prop_v3_text_grammar_is_v2_for_every_verb() {
    Prop::new("v3 renders/parses every verb exactly as v2").cases(80).run(|g| {
        let req = match g.usize(0, 10) {
            0 => Request::Ping,
            1 => Request::Stats,
            2 => Request::Util,
            3 => Request::Health,
            4 => Request::Shutdown,
            5 => Request::Sjob(g.u64(1, 1 << 40)),
            6 => Request::Scancel(g.u64(1, 1 << 40)),
            7 => Request::Wait {
                jobs: (0..g.usize(1, 5)).map(|_| g.u64(1, 1 << 32)).collect(),
                timeout_secs: g.f64(0.0, 600.0),
            },
            8 => Request::Squeue(SqueueFilter {
                user: g.bool(0.5).then(|| g.u64(1, 1 << 20) as u32),
                qos: g.bool(0.5).then(|| {
                    if g.bool(0.5) {
                        QosClass::Normal
                    } else {
                        QosClass::Spot
                    }
                }),
                state: None,
                limit: g.bool(0.5).then(|| g.usize(1, 10_000)),
            }),
            9 => {
                if g.bool(0.5) {
                    Request::Resume(ResumeTarget::Tag(gen_tag(g)))
                } else {
                    Request::Resume(ResumeTarget::Manifest(g.u64(1, 1 << 40)))
                }
            }
            _ => Request::Submit(
                SubmitSpec::new(
                    if g.bool(0.5) {
                        QosClass::Normal
                    } else {
                        QosClass::Spot
                    },
                    *g.pick(&[JobType::Individual, JobType::Array, JobType::TripleMode]),
                    g.u64(1, 4096) as u32,
                    g.u64(1, 1 << 20) as u32,
                )
                .with_run_secs(g.f64(0.0, 1.0e6))
                .with_count(g.u64(1, 1000) as u32),
            ),
        };
        let v2_line = codec::render_request(&req, ProtocolVersion::V2);
        let v3_line = codec::render_request(&req, ProtocolVersion::V3);
        assert_eq!(v2_line, v3_line, "v3 TEXT_REQ bodies are v2 text, byte-identical");
        assert_eq!(
            codec::parse_request(&v3_line, ProtocolVersion::V3).expect("v3 parse"),
            req,
            "typed round trip under the v3 dialect"
        );
        assert_eq!(
            codec::parse_request(&v2_line, ProtocolVersion::V2).expect("v2 parse"),
            req,
            "typed round trip under the v2 dialect"
        );
    });
}

#[test]
fn prop_v3_manifest_ack_round_trips_and_rejects_bad_totals() {
    Prop::new("v3 manifest ack codec round trip").cases(40).run(|g| {
        let mut next_id = 1u64;
        let mut jobs = 0u64;
        let n_acc = g.usize(0, 6);
        let mut accepted = Vec::with_capacity(n_acc);
        for i in 0..n_acc {
            let count = g.u64(1, 1000);
            let first = next_id;
            next_id += count + g.u64(0, 5);
            jobs += count;
            accepted.push(EntryAck {
                index: i as u32,
                first,
                last: first + count - 1,
                count,
            });
        }
        let rejected: Vec<EntryReject> = (0..g.usize(0, 4))
            .map(|i| EntryReject {
                index: (n_acc + i) as u32,
                error: ApiError::new(
                    *g.pick(&[
                        ErrorCode::BadArg,
                        ErrorCode::Overloaded,
                        ErrorCode::Unsupported,
                        ErrorCode::ReadOnly,
                    ]),
                    "entry refused",
                ),
            })
            .collect();
        let ack = ManifestAck {
            accepted,
            rejected,
            jobs,
            manifest: g.bool(0.5).then(|| g.u64(1, 1 << 40)),
        };
        let payload = codec::render_manifest_ack_v3(&ack);
        assert_eq!(
            codec::parse_manifest_ack_v3(&payload).expect("ack parse"),
            ack
        );

        // A jobs total its records don't sum to must be refused (the
        // client iterates those ranges; a lying peer can't inflate them).
        let mut bad = ack.clone();
        bad.jobs = bad.jobs.wrapping_add(1);
        assert!(codec::parse_manifest_ack_v3(&codec::render_manifest_ack_v3(&bad)).is_err());
    });
}

#[test]
fn prop_hostile_v3_payloads_error_without_panicking() {
    Prop::new("hostile v3 frames yield typed errors").cases(80).run(|g| {
        // Arbitrary junk: parsers must return, never panic or overread.
        let junk: Vec<u8> = (0..g.usize(0, 200)).map(|_| g.u64(0, 255) as u8).collect();
        let _ = codec::parse_msubmit_v3(&junk);
        let _ = codec::parse_manifest_ack_v3(&junk);

        // Every strict truncation of a valid manifest payload errors: the
        // parse is a deterministic prefix read, so a cut can only starve it.
        let m = Manifest {
            entries: (0..g.usize(1, 8)).map(|_| gen_entry(g)).collect(),
        };
        let payload = codec::render_msubmit_v3(&m);
        let cut = g.usize(0, payload.len() - 1);
        assert!(
            codec::parse_msubmit_v3(&payload[..cut]).is_err(),
            "truncated frame parsed at {cut}/{}",
            payload.len()
        );

        // Trailing bytes after the declared records error (desync guard).
        let mut extended = payload.clone();
        extended.push(g.u64(0, 255) as u8);
        assert!(codec::parse_msubmit_v3(&extended).is_err());

        // Length prefixes: zero and oversized refuse, short headers ask
        // for more bytes, a rendered frame's header measures its body.
        assert!(codec::decode_frame_header(&0u32.to_le_bytes()).is_err());
        let oversized = (codec::MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        assert!(codec::decode_frame_header(&oversized).is_err());
        assert!(matches!(codec::decode_frame_header(&[1, 2, 3]), Ok(None)));
        let frame = codec::v3_frame(codec::OP_MSUBMIT, &payload);
        match codec::decode_frame_header(&frame) {
            Ok(Some(len)) => assert_eq!(len, 1 + payload.len()),
            other => panic!("frame header misread: {other:?}"),
        }
    });
}

#[test]
fn prop_event_log_times_monotone_per_kind() {
    Prop::new("dispatch happens after recognition").cases(20).run(|g| {
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual);
        let mut sched = Scheduler::new(spotcloud::cluster::topology::tx2500(), cfg);
        let ids: Vec<JobId> = (0..g.usize(1, 30))
            .map(|_| {
                sched.submit(JobSpec::interactive(
                    UserId(1),
                    JobType::Array,
                    g.u64(1, 64) as u32,
                ))
            })
            .collect();
        sched.run_for(SimTime::from_secs(3600));
        for id in ids {
            let rec = sched.log().first(id, spotcloud::sched::LogKind::Recognized);
            let dis = sched.log().last(id, spotcloud::sched::LogKind::DispatchDone);
            if let (Some(r), Some(d)) = (rec, dis) {
                assert!(d >= r, "{id}: dispatched before recognized");
            }
        }
    });
}
