//! End-to-end daemon tests over real TCP: the coordinator stack as the e2e
//! example drives it, in miniature.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{
    client::Client, Daemon, DaemonConfig, ErrorCode, HealthState, ManifestBuilder, ManifestEntry,
    OverloadConfig, Server, SubmitSpec,
};
use spotcloud::job::{JobType, QosClass};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn spawn_cron_daemon() -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(160)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            // Keep retirement out of the TCP tests (wall-timing coupling).
            retire_grace_secs: Some(86_400.0),
            ..DaemonConfig::default()
        },
    );
    let pacer_daemon = Arc::clone(&daemon);
    pacer_daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

fn spawn_plain_daemon() -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual);
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(86_400.0),
            ..DaemonConfig::default()
        },
    );
    Arc::clone(&daemon).spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

#[test]
fn ten_thousand_entry_mixed_manifest_lands_in_one_rpc_over_tcp() {
    // The acceptance workload end to end: 10k heterogeneous entries —
    // interactive + spot, all three launch types, several users (the
    // shared workload::manifests::mixed generator, same shape as the CI
    // bench gate) — in ONE MSUBMIT line with per-entry job-id ranges.
    let (daemon, addr, server) = spawn_plain_daemon();
    let manifest = spotcloud::workload::manifests::mixed(7, 10_000, 5);
    let mut c = Client::connect_v2(&addr).unwrap();
    let ack = c.msubmit(&manifest).unwrap();
    assert_eq!(ack.rejected.len(), 0, "{:?}", ack.rejected.first());
    assert_eq!(ack.accepted.len(), 10_000);
    assert_eq!(ack.jobs, 10_000);
    let mut next = ack.accepted[0].first;
    for acc in &ack.accepted {
        assert_eq!(acc.first, next, "entry {} range not contiguous", acc.index);
        next = acc.last + 1;
    }
    // The tag round-trips to a remote SJOB (entry 1 is interactive in the
    // mixed shape: every 4th entry is spot, starting at 0).
    let detail = c.job(ack.accepted[1].first).unwrap();
    assert_eq!(detail.tag.as_deref(), Some("mixed-interactive"));
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn manifest_partial_accept_over_tcp() {
    let (daemon, addr, server) = spawn_plain_daemon();
    let mut c = Client::connect_v2(&addr).unwrap();
    let manifest = ManifestBuilder::new()
        .interactive(1, JobType::TripleMode, 608)
        .entry(ManifestEntry::new(QosClass::Normal, JobType::Array, 0, 1)) // tasks=0
        .spot(9, JobType::Array, 64)
        .build();
    let ack = c.msubmit(&manifest).unwrap();
    assert_eq!(ack.accepted.len(), 2);
    assert_eq!(ack.rejected.len(), 1);
    assert_eq!(ack.rejected[0].index, 1);
    assert_eq!(ack.rejected[0].error.code, ErrorCode::BadArg);
    // Accepted jobs are real: WAIT resolves the interactive entry.
    let ids: Vec<u64> = ack.entry(0).unwrap().ids().collect();
    let w = c.wait(&ids, 10.0).unwrap();
    assert!(!w.timed_out);
    assert_eq!(w.dispatched, 1);
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn v1_msubmit_is_typed_unsupported_and_never_desyncs() {
    let (daemon, addr, server) = spawn_plain_daemon();
    let mut c = Client::connect(&addr).unwrap(); // stays on v1
    let resp = c
        .request("MSUBMIT entries=1;qos=normal type=array tasks=4 user=1")
        .unwrap();
    assert!(resp.starts_with("ERR unsupported"), "{resp}");
    // The connection is fully usable afterwards — no desync, no close.
    assert_eq!(c.request("PING").unwrap(), "OK pong");
    let resp = c.request("SUBMIT normal array 4 1 60").unwrap();
    assert!(resp.starts_with("OK jobs="), "{resp}");
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn hostile_manifest_bodies_yield_typed_errors_and_keep_the_connection() {
    let (daemon, addr, server) = spawn_plain_daemon();
    let mut c = Client::connect_v2(&addr).unwrap();
    for (line, code) in [
        // Truncated body (fewer records than declared).
        ("MSUBMIT entries=3;qos=normal type=array tasks=4 user=1", "bad_arity"),
        // Padded body.
        (
            "MSUBMIT entries=1;qos=normal type=array tasks=4 user=1;qos=spot type=array tasks=4 user=9",
            "bad_arity",
        ),
        // Duplicate key.
        ("MSUBMIT entries=1;qos=normal qos=spot type=array tasks=4 user=1", "bad_arg"),
        // Unknown key.
        ("MSUBMIT entries=1;qos=normal type=array tasks=4 user=1 nope=1", "bad_arg"),
        // Header missing.
        ("MSUBMIT qos=normal type=array tasks=4 user=1", "bad_arity"),
    ] {
        let resp = c.request(line).unwrap();
        assert!(
            resp.starts_with(&format!("ERR code={code}")),
            "{line} -> {resp}"
        );
        // Still in sync after every rejection.
        let pong = c.request("PING").unwrap();
        assert_eq!(pong, "OK kind=pong", "after {line}");
    }
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn spliced_manifest_line_parses_exactly_once() {
    // Slow-loris-style: one MSUBMIT line delivered across odd chunk
    // boundaries (mid-record, mid-token) must yield exactly one parsed
    // request and one ack — never a splice, a desync, or a partial batch.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let (daemon, addr, server) = spawn_plain_daemon();
    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let read_response = |reader: &mut BufReader<TcpStream>| -> String {
        let mut out = String::new();
        loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).expect("read");
            assert!(n > 0, "server closed mid-response (got {out:?})");
            if line == "\n" {
                break;
            }
            out.push_str(&line);
        }
        out.trim_end_matches('\n').to_string()
    };
    writer.write_all(b"HELLO v2\n").unwrap();
    writer.flush().unwrap();
    assert_eq!(read_response(&mut reader), "OK kind=hello proto=v2");
    let line =
        "MSUBMIT entries=2;qos=normal type=triple tasks=64 user=1 tag=spliced;qos=spot type=array tasks=8 user=9\n";
    // Split mid-header, mid-record, and mid-token.
    let bytes = line.as_bytes();
    for chunk in [&bytes[..9], &bytes[9..20], &bytes[20..57], &bytes[57..90], &bytes[90..]] {
        writer.write_all(chunk).unwrap();
        writer.flush().unwrap();
        std::thread::sleep(Duration::from_millis(25));
    }
    let resp = read_response(&mut reader);
    assert!(resp.starts_with("OK kind=manifest_ack accepted=2 rejected=0 jobs=2"), "{resp}");
    // Exactly one MSUBMIT parsed, and the connection still serves.
    writer.write_all(b"PING\n").unwrap();
    writer.flush().unwrap();
    assert_eq!(read_response(&mut reader), "OK kind=pong");
    let msubmits = daemon
        .metrics
        .command_counts()
        .into_iter()
        .find(|(cmd, _)| *cmd == "MSUBMIT")
        .map(|(_, n)| n)
        .unwrap();
    assert_eq!(msubmits, 1);
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn degenerate_submissions_are_typed_errors_over_tcp() {
    let (daemon, addr, server) = spawn_plain_daemon();
    // v1 grammar.
    let mut v1 = Client::connect(&addr).unwrap();
    for line in [
        "SUBMIT normal array 0 1",      // tasks=0
        "SUBMIT normal array 4 1 60 0", // count=0
    ] {
        let resp = v1.request(line).unwrap();
        assert!(resp.starts_with("ERR bad_arg"), "{line} -> {resp}");
    }
    // v2 grammar.
    let mut v2 = Client::connect_v2(&addr).unwrap();
    for line in [
        "SUBMIT qos=normal type=array tasks=0 user=1",
        "SUBMIT qos=normal type=array tasks=4 user=1 count=0",
        "MSUBMIT entries=1;qos=normal type=array tasks=4 user=1 cores_per_task=0",
    ] {
        let resp = v2.request(line).unwrap();
        // cores_per_task=0 arrives via the manifest path: it parses, then
        // admission rejects the entry (partial accept of a 1-entry
        // manifest = zero accepted, one typed reject).
        if line.starts_with("MSUBMIT") {
            assert!(
                resp.contains("accepted=0 rejected=1") && resp.contains("code=bad_arg"),
                "{line} -> {resp}"
            );
        } else {
            assert!(resp.starts_with("ERR code=bad_arg"), "{line} -> {resp}");
        }
    }
    // Nothing landed.
    let rows = v2.squeue(&Default::default()).unwrap();
    assert!(rows.is_empty(), "{rows:?}");
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn spot_then_interactive_over_tcp() {
    let (daemon, addr, server) = spawn_cron_daemon();
    let mut c = Client::connect(&addr).unwrap();

    // Load spot work up to the agent's ceiling.
    for _ in 0..4 {
        let r = c.request("SUBMIT spot triple 96 9 86400").unwrap();
        assert!(r.starts_with("OK"), "{r}");
    }
    // Interactive lands on the reserve.
    let r = c.request("SUBMIT normal triple 160 1 120").unwrap();
    assert!(r.starts_with("OK"), "{r}");

    // Wait until the interactive job's scheduling latency is harvested.
    let deadline = Instant::now() + Duration::from_secs(10);
    while daemon.metrics.sched_latency().count() == 0 {
        assert!(Instant::now() < deadline, "interactive job never dispatched");
        std::thread::sleep(Duration::from_millis(5));
    }
    let lat = daemon.metrics.sched_latency();
    assert!(
        lat.max() < 5_000_000_000,
        "interactive latency {}ns should be ~baseline",
        lat.max()
    );

    let util = c.request("UTIL").unwrap();
    assert!(util.contains("total_cores=608"), "{util}");

    let _ = c.request("SHUTDOWN");
    server.join().unwrap();
}

#[test]
fn stats_reflect_scheduler_activity() {
    let (_daemon, addr, server) = spawn_cron_daemon();
    let mut c = Client::connect(&addr).unwrap();
    c.request("SUBMIT spot triple 96 9 600").unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let stats = c.request("STATS").unwrap();
    assert!(stats.contains("dispatches="), "{stats}");
    assert!(stats.contains("cron_passes="), "{stats}");
    assert!(stats.contains("scorer=native"), "{stats}");
    let _ = c.request("SHUTDOWN");
    server.join().unwrap();
}

/// A daemon with `shards` scheduler shards behind a `bind_sharded` server
/// asking for the same number of reactor shards (non-Linux builds fall
/// back to the portable server; the scheduler sharding still applies).
fn spawn_sharded_daemon(shards: usize) -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        // The cross-shard tests queue hundreds of jobs per user; per-user
        // admission caps are not what they exercise.
        .with_user_limit(100_000);
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(86_400.0),
            shard_count: shards,
            ..DaemonConfig::default()
        },
    );
    Arc::clone(&daemon).spawn_pacer();
    let server = Server::bind_sharded(Arc::clone(&daemon), "127.0.0.1:0", 4, shards).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

#[test]
fn multi_partition_manifest_is_atomic_and_contiguous_across_shards() {
    // One MSUBMIT whose entries alternate between the interactive and spot
    // partitions — i.e. between the two scheduler shards. The global id
    // allocator must still hand out one contiguous run across the whole
    // manifest, and the ack must cover every entry exactly once.
    let (daemon, addr, server) = spawn_sharded_daemon(2);
    assert_eq!(daemon.shard_count(), 2);
    let mut b = ManifestBuilder::new();
    for i in 0..40u32 {
        b = if i % 2 == 0 {
            b.interactive(1 + i % 5, JobType::Individual, 2)
        } else {
            b.spot(50 + i % 3, JobType::Individual, 2)
        };
    }
    let mut c = Client::connect_v2(&addr).unwrap();
    let ack = c.msubmit(&b.build()).unwrap();
    assert_eq!(ack.rejected.len(), 0, "{:?}", ack.rejected.first());
    assert_eq!(ack.accepted.len(), 40);
    assert_eq!(ack.jobs, 80);
    let mut next = ack.accepted[0].first;
    for acc in &ack.accepted {
        assert_eq!(acc.first, next, "entry {} range not contiguous", acc.index);
        assert_eq!(acc.count, 2, "entry {}", acc.index);
        next = acc.last + 1;
    }
    // Both shards really took their halves, and each job answers SJOB.
    let first_detail = c.job(ack.accepted[0].first).unwrap();
    assert_eq!(first_detail.user, 1);
    let second_detail = c.job(ack.accepted[1].first).unwrap();
    assert_eq!(second_detail.user, 51);
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn wait_parked_on_the_front_door_resolves_from_the_spot_shard_exactly_once() {
    // More spot jobs than the spot shard has cores: the WAIT must park on
    // whichever reactor shard owns the connection and resolve only when
    // scheduler shard 1 (spot) has dispatched every job — then exactly
    // once, which the parked/resumed counter balance proves.
    let (daemon, addr, server) = spawn_sharded_daemon(2);
    let mut c = Client::connect_v2(&addr).unwrap();
    let ack = c
        .submit(&SubmitSpec::new(QosClass::Spot, JobType::Array, 400, 9).with_run_secs(5.0))
        .unwrap();
    assert_eq!(ack.count, 400);
    let ids: Vec<u64> = ack.ids().collect();
    let w = c.wait(&ids, 30.0).unwrap();
    assert!(!w.timed_out, "{w:?}");
    assert_eq!(w.dispatched, 400, "{w:?}");
    // The work landed on the spot shard, not shard 0.
    let spot_dispatches = daemon.with_shard(1, |s| s.stats().dispatches);
    assert!(spot_dispatches >= 400, "spot shard dispatched {spot_dispatches}");
    // Exactly-once wake: quiesce, then the counters must balance.
    std::thread::sleep(Duration::from_millis(100));
    let parked = daemon.metrics.waits_parked.load(std::sync::atomic::Ordering::Relaxed);
    let resumed = daemon.metrics.waits_resumed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(parked, resumed, "a parked WAIT was lost or woken twice");
    // The connection survives its parked WAIT.
    c.ping().unwrap();
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn scancel_races_cross_shard_activity_without_breaking_invariants() {
    // Cancellers hammer the spot shard's jobs from their own connections
    // while a submitter loads the interactive shard — cancellation racing
    // dispatch/completion on one shard and admission on the other. Every
    // shard's scheduler must hold its invariants afterwards.
    let (daemon, addr, server) = spawn_sharded_daemon(2);
    let mut c = Client::connect_v2(&addr).unwrap();
    let ack = c
        .submit(&SubmitSpec::new(QosClass::Spot, JobType::Array, 300, 9).with_run_secs(600.0))
        .unwrap();
    let ids: Vec<u64> = ack.ids().collect();
    let cancellers: Vec<_> = ids
        .chunks(100)
        .map(|chunk| {
            let addr = addr.clone();
            let chunk = chunk.to_vec();
            std::thread::spawn(move || {
                let mut c = Client::connect_v2(&addr).unwrap();
                for id in chunk {
                    // Racing a completion/requeue: an already-terminal job
                    // is a typed error, never a dead connection.
                    let _ = c.cancel(id);
                }
                c.ping().unwrap();
            })
        })
        .collect();
    let submit_addr = addr.clone();
    let submitter = std::thread::spawn(move || {
        let mut c = Client::connect_v2(&submit_addr).unwrap();
        for i in 0..120u32 {
            c.submit(&SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 1 + i % 4))
                .unwrap();
        }
        c.ping().unwrap();
    });
    for t in cancellers {
        t.join().unwrap();
    }
    submitter.join().unwrap();
    for idx in 0..daemon.shard_count() {
        daemon.with_shard(idx, |s| s.check_invariants())
            .unwrap_or_else(|e| panic!("shard {idx} invariants violated: {e}"));
    }
    c.ping().unwrap();
    daemon.shutdown();
    server.join().unwrap();
}

/// Shutdown with WAITs parked across multiple reactor shards: every shard
/// drains — each parked waiter gets a final answer (or an orderly close),
/// the counters balance, and `serve` returns. Linux-only because the
/// per-shard parked gauges live on the reactor.
#[cfg(target_os = "linux")]
#[test]
fn shutdown_drains_parked_waits_on_every_reactor_shard() {
    // No pacer: the virtual clock is frozen, so a queued job can never
    // dispatch and the WAITs below stay parked until shutdown.
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(100_000);
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            shard_count: 2,
            ..DaemonConfig::default()
        },
    );
    let server = Server::bind_sharded(Arc::clone(&daemon), "127.0.0.1:0", 4, 2).unwrap();
    assert_eq!(server.reactor_shards(), 2);
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.serve());

    // One spot job over capacity-one-core semantics doesn't matter — with
    // no pacer nothing dispatches, so any WAIT on it parks forever.
    let mut c = Client::connect_v2(&addr).unwrap();
    let ack = c
        .submit(&SubmitSpec::new(QosClass::Spot, JobType::Individual, 1, 9).with_run_secs(60.0))
        .unwrap();
    let id = ack.first;
    let waiters: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut w = Client::connect_v2(&addr).unwrap();
                // Resolved by shutdown, not by time: the result (timeout
                // response or orderly close) only has to arrive.
                let _ = w.wait(&[id], 120.0);
            })
        })
        .collect();

    // Wait until all six are parked on the reactors (whichever shards the
    // kernel spread them across).
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let parked: u64 = daemon
            .metrics
            .reactor_shards()
            .iter()
            .map(|s| s.parked_waits.load(std::sync::atomic::Ordering::Relaxed))
            .sum();
        if parked >= 6 {
            break;
        }
        assert!(Instant::now() < deadline, "waiters never parked (saw {parked})");
        std::thread::sleep(Duration::from_millis(5));
    }

    daemon.shutdown();
    server_thread.join().unwrap();
    // Shutdown drained every shard: all waiter connections got unblocked.
    for w in waiters {
        w.join().unwrap();
    }
    let parked = daemon.metrics.waits_parked.load(std::sync::atomic::Ordering::Relaxed);
    let resumed = daemon.metrics.waits_resumed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(parked, resumed, "a parked WAIT was dropped at shutdown");
}

// ---- overload control plane ------------------------------------------------

/// A daemon with the overload control plane armed (per-user token buckets,
/// admission budget, health probe riding the pacer).
fn spawn_overload_daemon(ov: OverloadConfig) -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        // Shedding is what these tests exercise, not per-user admission caps.
        .with_user_limit(100_000);
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(86_400.0),
            overload: ov,
            ..DaemonConfig::default()
        },
    );
    Arc::clone(&daemon).spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

#[test]
fn batch_flood_sheds_typed_while_interactive_waits_resolve() {
    let (daemon, addr, server) = spawn_overload_daemon(OverloadConfig {
        user_rate: 0.001,
        user_burst: 3.0,
        ..OverloadConfig::default()
    });
    // Interactive session on its own connection and user.
    let mut interactive = Client::connect_v2(&addr).unwrap();
    let ack = interactive
        .submit(&SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 1).with_run_secs(1.0))
        .unwrap();
    // Batch flood: user 9 burns its burst, then every further submission
    // sheds with the typed `overloaded` + retry hint on the wire.
    let mut flood = Client::connect_v2(&addr).unwrap();
    let (mut ok, mut shed) = (0u64, 0u64);
    for _ in 0..40 {
        let resp = flood.request("SUBMIT qos=spot type=array tasks=4 user=9").unwrap();
        if resp.starts_with("OK kind=submit_ack") {
            ok += 1;
        } else {
            assert!(resp.starts_with("ERR code=overloaded retry_after_ms="), "{resp}");
            shed += 1;
        }
    }
    assert_eq!(ok, 3, "the burst admits, the flood sheds");
    assert_eq!(shed, 37);
    // The flood never touched the interactive path: the WAIT resolves.
    let ids: Vec<u64> = ack.ids().collect();
    let w = interactive.wait(&ids, 10.0).unwrap();
    assert!(!w.timed_out, "{w:?}");
    // Keep the pressure on until a probe reports it — `shedding` is a
    // derived observation, so the flood must still be hot when it lands.
    let deadline = Instant::now() + Duration::from_secs(5);
    while interactive.health().unwrap().state != HealthState::Shedding {
        let resp = flood.request("SUBMIT qos=spot type=array tasks=4 user=9").unwrap();
        assert!(resp.starts_with("ERR code=overloaded"), "{resp}");
        shed += 1;
        assert!(Instant::now() < deadline, "daemon never reported shedding");
        std::thread::sleep(Duration::from_millis(5));
    }
    let h = interactive.health().unwrap();
    assert!(h.rate_limited >= shed, "{h:?}");
    // Flood gone: the daemon recovers to healthy within a probe interval.
    let deadline = Instant::now() + Duration::from_secs(5);
    while interactive.health().unwrap().state != HealthState::Healthy {
        assert!(Instant::now() < deadline, "daemon never recovered to healthy");
        std::thread::sleep(Duration::from_millis(5));
    }
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn rate_limited_user_cannot_starve_another_user() {
    let (daemon, addr, server) = spawn_overload_daemon(OverloadConfig {
        user_rate: 0.001,
        user_burst: 8.0,
        ..OverloadConfig::default()
    });
    let mut hog = Client::connect_v2(&addr).unwrap();
    let mut victim = Client::connect_v2(&addr).unwrap();
    let mut hog_shed = 0u64;
    for i in 0..24u32 {
        let r = hog.request("SUBMIT qos=spot type=array tasks=8 user=9").unwrap();
        if !r.starts_with("OK kind=submit_ack") {
            assert!(r.starts_with("ERR code=overloaded retry_after_ms="), "{r}");
            hog_shed += 1;
        }
        // Interleaved: user 1 spends its own, independent budget.
        if i % 4 == 0 {
            let r = victim
                .request("SUBMIT qos=normal type=individual tasks=1 user=1")
                .unwrap();
            assert!(r.starts_with("OK kind=submit_ack"), "user 1 starved: {r}");
        }
    }
    assert_eq!(hog_shed, 16, "user 9: 8 admitted on the burst, 16 shed");
    // STATS carries the shed block for operators.
    let stats = victim.stats().unwrap();
    let h = stats.health.expect("stats health block");
    assert!(h.rate_limited >= 16, "{h:?}");
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn expired_deadline_mid_stream_never_reaches_the_scheduler() {
    // A chunked MSUBMIT whose deadline budget runs out between parts: the
    // next part is refused with the typed `overloaded` (retry_after_ms=0 —
    // retrying won't help, the budget is spent), the partial manifest is
    // discarded, and the scheduler never sees a job. Counter-asserted.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let (daemon, addr, server) = spawn_overload_daemon(OverloadConfig::default());
    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let read_response = |reader: &mut BufReader<TcpStream>| -> String {
        let mut out = String::new();
        loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).expect("read");
            assert!(n > 0, "server closed mid-response (got {out:?})");
            if line == "\n" {
                break;
            }
            out.push_str(&line);
        }
        out.trim_end_matches('\n').to_string()
    };
    writer.write_all(b"HELLO v2.1\n").unwrap();
    writer.flush().unwrap();
    assert_eq!(read_response(&mut reader), "OK kind=hello proto=v2.1");
    writer
        .write_all(
            b"deadline_ms=50 MSUBMIT entries=2 part=1/2;qos=normal type=array tasks=4 user=1\n",
        )
        .unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert!(resp.starts_with("OK kind=chunk_ack part=1"), "{resp}");
    // Burn the budget, then deliver part 2.
    std::thread::sleep(Duration::from_millis(200));
    writer
        .write_all(b"MSUBMIT entries=2 part=2/2;qos=spot type=array tasks=8 user=9\n")
        .unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert!(resp.starts_with("ERR code=overloaded retry_after_ms=0"), "{resp}");
    // Nothing reached the scheduler, and the drop was counted.
    assert_eq!(
        daemon.metrics.deadline_expired.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    let mut c = Client::connect_v2(&addr).unwrap();
    assert!(c.squeue(&Default::default()).unwrap().is_empty());
    let h = c.health().unwrap();
    assert_eq!(h.deadline_expired, 1, "{h:?}");
    // The connection is still in sync: a fresh stream from part 1 lands.
    writer
        .write_all(b"MSUBMIT entries=1 part=1/1;qos=normal type=array tasks=4 user=1\n")
        .unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert!(resp.starts_with("OK kind=manifest_ack accepted=1"), "{resp}");
    daemon.shutdown();
    server.join().unwrap();
}

/// A connection that stops reading while pinned over the write-backlog cap
/// is evicted after the grace period — counted, closed, memory freed.
/// Linux-only: eviction lives on the reactor's timer wheel.
#[cfg(target_os = "linux")]
#[test]
fn slow_consumer_is_evicted_and_its_connection_closed() {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    let (daemon, addr, server) = spawn_overload_daemon(OverloadConfig::default());
    // Enough queued jobs that one SQUEUE response is megabytes of rows.
    let mut c = Client::connect_v2(&addr).unwrap();
    c.submit(
        &SubmitSpec::new(QosClass::Spot, JobType::Individual, 1, 9)
            .with_run_secs(86_400.0)
            .with_count(30_000),
    )
    .unwrap();
    // The slow consumer: pipeline SQUEUEs and never read a byte. Kernel
    // buffers absorb a few responses; the rest pins the reactor-side
    // write backlog over MAX_WRITE_BACKLOG.
    let mut slow = TcpStream::connect(&addr).unwrap();
    slow.set_nodelay(true).unwrap();
    for _ in 0..16 {
        slow.write_all(b"SQUEUE\n").unwrap();
    }
    slow.flush().unwrap();
    // The eviction timer fires after the grace period (5s): the counter
    // moves and the socket is closed under the reader.
    let deadline = Instant::now() + Duration::from_secs(20);
    while daemon.metrics.conns_evicted.load(std::sync::atomic::Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "slow consumer never evicted");
        std::thread::sleep(Duration::from_millis(50));
    }
    // Drain until EOF: a closed connection, not a hung one. (The kernel
    // still delivers what was buffered before the close.)
    slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 64 * 1024];
    loop {
        match slow.read(&mut buf) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e) => panic!("evicted socket should EOF, not {e}"),
        }
    }
    // The reactor shard counted it too.
    let shard_evictions: u64 = daemon
        .metrics
        .reactor_shards()
        .iter()
        .map(|s| s.evictions.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert!(shard_evictions >= 1);
    // Healthy daemon throughout: a well-behaved client still serves.
    c.ping().unwrap();
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn v3_binary_msubmit_end_to_end_over_tcp() {
    // The full v3 binary session against the real server: HELLO v3
    // upgrade, framed text verbs, a 1000-entry binary MSUBMIT (varint
    // records, no text rendering), typed reads of what landed, and the
    // mixed-traffic STATS gauges the new dialect reports.
    let (daemon, addr, server) = spawn_plain_daemon();
    let mut c = Client::connect_v3(&addr).unwrap();
    assert_eq!(c.version(), spotcloud::coordinator::ProtocolVersion::V3);
    c.ping().unwrap();
    let manifest = spotcloud::workload::manifests::mixed(7, 1_000, 5);
    let ack = c.msubmit(&manifest).unwrap();
    assert_eq!(ack.rejected.len(), 0, "{:?}", ack.rejected.first());
    assert_eq!(ack.accepted.len(), 1_000);
    assert_eq!(ack.jobs, 1_000);
    let mut next = ack.accepted[0].first;
    for acc in &ack.accepted {
        assert_eq!(acc.first, next, "entry {} range not contiguous", acc.index);
        next = acc.last + 1;
    }
    // Tags interned straight from the binary payload round-trip to SJOB.
    let detail = c.job(ack.accepted[1].first).unwrap();
    assert_eq!(detail.tag.as_deref(), Some("mixed-interactive"));
    // WAIT resolutions are framed too (the parked path).
    let w = c.wait(&[ack.accepted[1].first], 10.0).unwrap();
    assert!(!w.timed_out);
    // STATS carries the user gauges over the framed transport.
    let stats = c.stats().unwrap();
    let users = stats.users.expect("v3 STATS carries user gauges");
    assert!(users.users_tracked >= 1, "{users:?}");
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn v3_hostile_frames_recover_typed_or_close_without_desync() {
    use spotcloud::coordinator::codec;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    let (daemon, addr, server) = spawn_plain_daemon();

    let read_frame = |reader: &mut BufReader<TcpStream>| -> (u8, Vec<u8>) {
        let mut header = [0u8; 4];
        reader.read_exact(&mut header).expect("frame header");
        let len = u32::from_le_bytes(header) as usize;
        assert!(len >= 1, "zero-length frame from server");
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).expect("frame body");
        let payload = body.split_off(1);
        (body[0], payload)
    };

    // Session 1: in-frame garbage is a typed error, the connection lives.
    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"HELLO v3\n").unwrap();
    writer.flush().unwrap();
    let mut hello = String::new();
    reader.read_line(&mut hello).unwrap();
    assert_eq!(hello, "OK kind=hello proto=v3\n");
    let mut blank = String::new();
    reader.read_line(&mut blank).unwrap();
    assert_eq!(blank, "\n", "the HELLO ack itself is still text");

    // Unknown opcode: typed unsupported, then the conn still serves.
    writer.write_all(&codec::v3_frame(0x7f, b"")).unwrap();
    writer.flush().unwrap();
    let (op, payload) = read_frame(&mut reader);
    assert_eq!(op, codec::OP_TEXT_RESP);
    let body = String::from_utf8(payload).unwrap();
    assert!(body.starts_with("ERR code=unsupported"), "{body}");

    // A corrupt MSUBMIT payload: typed error, no desync.
    writer.write_all(&codec::v3_frame(codec::OP_MSUBMIT, &[0xff; 6])).unwrap();
    writer.flush().unwrap();
    let (op, payload) = read_frame(&mut reader);
    assert_eq!(op, codec::OP_TEXT_RESP);
    let body = String::from_utf8(payload).unwrap();
    assert!(body.starts_with("ERR code="), "{body}");

    // Renegotiating from inside a frame is refused, typed.
    writer.write_all(&codec::v3_frame(codec::OP_TEXT_REQ, b"HELLO v2")).unwrap();
    writer.flush().unwrap();
    let (op, payload) = read_frame(&mut reader);
    assert_eq!(op, codec::OP_TEXT_RESP);
    let body = String::from_utf8(payload).unwrap();
    assert!(body.starts_with("ERR code=unsupported"), "{body}");

    // After all that abuse, a framed PING still answers.
    writer.write_all(&codec::v3_frame(codec::OP_TEXT_REQ, b"PING")).unwrap();
    writer.flush().unwrap();
    let (op, payload) = read_frame(&mut reader);
    assert_eq!(op, codec::OP_TEXT_RESP);
    assert_eq!(String::from_utf8(payload).unwrap(), "OK kind=pong");

    // Session 2: an oversized length prefix is unrecoverable — typed
    // error frame, then close (the stream position is unknowable).
    let stream2 = TcpStream::connect(&addr).unwrap();
    stream2.set_nodelay(true).unwrap();
    let mut writer2 = stream2.try_clone().unwrap();
    let mut reader2 = BufReader::new(stream2);
    writer2.write_all(b"HELLO v3\n").unwrap();
    writer2.flush().unwrap();
    let mut hello2 = String::new();
    reader2.read_line(&mut hello2).unwrap();
    assert_eq!(hello2, "OK kind=hello proto=v3\n");
    let mut blank2 = String::new();
    reader2.read_line(&mut blank2).unwrap();
    let huge = ((codec::MAX_FRAME_BYTES as u32) + 2).to_le_bytes();
    writer2.write_all(&huge).unwrap();
    writer2.flush().unwrap();
    let (op, payload) = read_frame(&mut reader2);
    assert_eq!(op, codec::OP_TEXT_RESP);
    let body = String::from_utf8(payload).unwrap();
    assert!(body.starts_with("ERR code="), "{body}");
    let mut rest = Vec::new();
    reader2.read_to_end(&mut rest).expect("read to EOF");
    assert!(rest.is_empty(), "server must close after a bad length prefix");

    // The daemon is unharmed: a well-behaved v3 client still works.
    let mut c = Client::connect_v3(&addr).unwrap();
    c.ping().unwrap();
    daemon.shutdown();
    server.join().unwrap();
}

#[test]
fn malformed_requests_do_not_kill_the_connection() {
    let (_daemon, addr, server) = spawn_cron_daemon();
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.request("GARBAGE").unwrap().starts_with("ERR"));
    assert!(c.request("SUBMIT bad args here x").unwrap().starts_with("ERR"));
    // Connection still works.
    assert_eq!(c.request("PING").unwrap(), "OK pong");
    let _ = c.request("SHUTDOWN");
    server.join().unwrap();
}
