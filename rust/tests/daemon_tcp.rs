//! End-to-end daemon tests over real TCP: the coordinator stack as the e2e
//! example drives it, in miniature.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{client::Client, Daemon, DaemonConfig, Server};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn spawn_cron_daemon() -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(160)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            // Keep retirement out of the TCP tests (wall-timing coupling).
            retire_grace_secs: Some(86_400.0),
            ..DaemonConfig::default()
        },
    );
    let pacer_daemon = Arc::clone(&daemon);
    pacer_daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

#[test]
fn spot_then_interactive_over_tcp() {
    let (daemon, addr, server) = spawn_cron_daemon();
    let mut c = Client::connect(&addr).unwrap();

    // Load spot work up to the agent's ceiling.
    for _ in 0..4 {
        let r = c.request("SUBMIT spot triple 96 9 86400").unwrap();
        assert!(r.starts_with("OK"), "{r}");
    }
    // Interactive lands on the reserve.
    let r = c.request("SUBMIT normal triple 160 1 120").unwrap();
    assert!(r.starts_with("OK"), "{r}");

    // Wait until the interactive job's scheduling latency is harvested.
    let deadline = Instant::now() + Duration::from_secs(10);
    while daemon.metrics.sched_latency().count() == 0 {
        assert!(Instant::now() < deadline, "interactive job never dispatched");
        std::thread::sleep(Duration::from_millis(5));
    }
    let lat = daemon.metrics.sched_latency();
    assert!(
        lat.max() < 5_000_000_000,
        "interactive latency {}ns should be ~baseline",
        lat.max()
    );

    let util = c.request("UTIL").unwrap();
    assert!(util.contains("total_cores=608"), "{util}");

    let _ = c.request("SHUTDOWN");
    server.join().unwrap();
}

#[test]
fn stats_reflect_scheduler_activity() {
    let (_daemon, addr, server) = spawn_cron_daemon();
    let mut c = Client::connect(&addr).unwrap();
    c.request("SUBMIT spot triple 96 9 600").unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let stats = c.request("STATS").unwrap();
    assert!(stats.contains("dispatches="), "{stats}");
    assert!(stats.contains("cron_passes="), "{stats}");
    assert!(stats.contains("scorer=native"), "{stats}");
    let _ = c.request("SHUTDOWN");
    server.join().unwrap();
}

#[test]
fn malformed_requests_do_not_kill_the_connection() {
    let (_daemon, addr, server) = spawn_cron_daemon();
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.request("GARBAGE").unwrap().starts_with("ERR"));
    assert!(c.request("SUBMIT bad args here x").unwrap().starts_with("ERR"));
    // Connection still works.
    assert_eq!(c.request("PING").unwrap(), "OK pong");
    let _ = c.request("SHUTDOWN");
    server.join().unwrap();
}
