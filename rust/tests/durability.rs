//! Durability end to end: the crash-point matrix over the fault-injection
//! harness, torn-tail healing, and the kill → recover → `RESUME` workflow
//! over real TCP.
//!
//! The contract under test (see PROTOCOL.md §Durability):
//!
//! * **No acked loss** — a submission the client saw an `OK` for exists
//!   after recovery, whatever the crash point.
//! * **No unacked resurrection under `fsync=always`** — a submission that
//!   failed before its record was durable is *gone* after recovery.
//! * **At-least-once edge** — a crash after the fsync but before the ack
//!   resurrects work the client never saw acked; `RESUME` is the
//!   idempotency tool.
//! * A torn final record (crash mid-write) truncates; it is never fatal.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{
    Client, Daemon, DaemonConfig, DurabilityConfig, ErrorCode, FaultPoint, FsyncPolicy,
    ManifestBuilder, Request, Response, RetryPolicy, Server, SqueueFilter, SubmitSpec,
};
use spotcloud::job::{JobType, QosClass};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use spotcloud::testkit::crash::TempDir;
use std::sync::Arc;

fn sched_cfg() -> SchedulerConfig {
    SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
}

/// A journaling daemon whose virtual clock is frozen (`speedup: 0`):
/// admitted jobs stay pending forever, so "what survived the crash" is
/// exactly "what was admitted".
fn frozen_cfg(dcfg: DurabilityConfig) -> DaemonConfig {
    DaemonConfig {
        speedup: 0.0,
        pacer_tick_ms: 1,
        durability: Some(dcfg),
        ..DaemonConfig::default()
    }
}

/// Submit one spot array job; `Ok(first_id)` on ack, `Err(code)` on a
/// typed refusal.
fn submit_spot(d: &Daemon, tasks: u32) -> Result<u64, ErrorCode> {
    match d.handle(Request::Submit(SubmitSpec::new(
        QosClass::Spot,
        JobType::Array,
        tasks,
        9,
    ))) {
        Response::SubmitAck(a) => Ok(a.first),
        Response::Error(e) => Err(e.code),
        other => panic!("{other:?}"),
    }
}

fn job_count(d: &Daemon) -> usize {
    match d.handle(Request::Squeue(SqueueFilter::default())) {
        Response::Jobs(rows) => rows.len(),
        other => panic!("{other:?}"),
    }
}

#[test]
fn crash_before_fsync_loses_only_the_unacked_submission() {
    let tmp = TempDir::new("spotcloud-dur-afterappend");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let faults = dcfg.faults.clone();
    let cfg = frozen_cfg(dcfg);
    let acked;
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        acked = submit_spot(&d, 8).expect("pre-crash submission acks");
        // Crash after the record is written but before the fsync: the
        // record is lost AND the client was never acked.
        faults.arm(FaultPoint::AfterAppend);
        let err = submit_spot(&d, 16).expect_err("faulted submission must not ack");
        assert_eq!(err, ErrorCode::Internal);
        d.shutdown();
    }
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    // Exactly the acked admission replays — nothing resurrected.
    assert_eq!(report.admits_replayed, 1, "{report}");
    assert_eq!(job_count(&d), 1);
    match d.handle(Request::Sjob(acked)) {
        Response::Job(_) => {}
        other => panic!("acked job lost across recovery: {other:?}"),
    }
}

#[test]
fn crash_after_fsync_resurrects_the_durable_unacked_submission() {
    let tmp = TempDir::new("spotcloud-dur-afterfsync");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let faults = dcfg.faults.clone();
    let cfg = frozen_cfg(dcfg);
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        submit_spot(&d, 8).expect("pre-crash submission acks");
        // Crash after the record is durable but before the ack: the
        // documented at-least-once edge.
        faults.arm(FaultPoint::AfterFsync);
        let err = submit_spot(&d, 16).expect_err("the crash swallowed the ack");
        assert_eq!(err, ErrorCode::Internal);
        d.shutdown();
    }
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    // Both records were durable, so both replay — the unacked one
    // resurrects (clients dedupe via RESUME, not via the journal).
    assert_eq!(report.admits_replayed, 2, "{report}");
    assert_eq!(job_count(&d), 2);
}

#[test]
fn crash_mid_checkpoint_falls_back_to_the_previous_segments() {
    let tmp = TempDir::new("spotcloud-dur-midckpt");
    let dcfg = DurabilityConfig::new(tmp.path())
        .with_fsync(FsyncPolicy::Always)
        .with_checkpoint_every(2);
    let faults = dcfg.faults.clone();
    let cfg = frozen_cfg(dcfg);
    let (a, b);
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        a = submit_spot(&d, 8).expect("first ack");
        // The second admission trips the checkpoint stride; the rotation
        // tears mid-write. The admission itself was already durable and
        // acked in the old segment.
        faults.arm(FaultPoint::MidCheckpoint);
        b = submit_spot(&d, 16).expect("second ack (checkpoint failure is not an admission failure)");
        // The poisoned journal degrades the daemon to read-only.
        assert_eq!(submit_spot(&d, 4), Err(ErrorCode::Internal));
        d.shutdown();
    }
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    assert!(
        report.segments_discarded >= 1,
        "the torn rotation segment must be discarded: {report}"
    );
    assert_eq!(report.admits_replayed, 2, "{report}");
    assert_eq!(job_count(&d), 2);
    for id in [a, b] {
        match d.handle(Request::Sjob(id)) {
            Response::Job(_) => {}
            other => panic!("acked job {id} lost across recovery: {other:?}"),
        }
    }
}

#[test]
fn torn_final_record_is_truncated_not_fatal() {
    let tmp = TempDir::new("spotcloud-dur-torn");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let cfg = frozen_cfg(dcfg);
    let acked;
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        acked = submit_spot(&d, 8).expect("pre-crash submission acks");
        d.shutdown();
    }
    // A crash mid-write leaves a partial frame at the tail of the newest
    // segment; emulate it with garbage too short to even hold a header.
    let newest = std::fs::read_dir(tmp.path())
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "wal"))
        .max()
        .expect("journal segment exists");
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new().append(true).open(&newest).unwrap();
    f.write_all(&[0xFF; 7]).unwrap();
    drop(f);
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    assert_eq!(report.torn_bytes, 7, "{report}");
    match d.handle(Request::Sjob(acked)) {
        Response::Job(_) => {}
        other => panic!("acked job lost to a torn tail: {other:?}"),
    }
}

#[test]
fn tcp_kill_recover_resume_collects_exactly_the_unsettled_entries() {
    // The acceptance workflow end to end: a client submits a tagged
    // manifest, the daemon "crashes" before anything dispatches, a new
    // daemon recovers from the journal, and the client re-attaches with
    // retry/backoff + RESUME, waiting out exactly the entries that had not
    // settled.
    let tmp = TempDir::new("spotcloud-dur-tcp");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let cfg = frozen_cfg(dcfg); // frozen: nothing settles pre-crash
    let (manifest_id, acked_spans);
    {
        let daemon = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve());
        let mut c = Client::connect_v2(&addr).unwrap();
        let m = ManifestBuilder::new()
            .interactive(1, JobType::TripleMode, 608)
            .last(|e| e.with_tag("nightly"))
            .interactive(2, JobType::TripleMode, 608)
            .build();
        let ack = c.msubmit(&m).unwrap();
        manifest_id = ack.manifest.expect("a journaling daemon assigns manifest ids");
        acked_spans = ack.accepted.clone();
        daemon.shutdown(); // kill: no drain, no goodbye
        handle.join().unwrap();
    }
    // Recover on the same journal — this time with a live clock.
    let cfg = DaemonConfig {
        speedup: 10_000.0,
        ..cfg
    };
    let (daemon, report) =
        Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    assert_eq!(report.manifests_restored, 1, "{report}");
    daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    // The resuming client reconnects with backoff, then re-attaches by tag.
    let mut c = Client::connect_v2_retry(&addr, &RetryPolicy::default()).unwrap();
    let info = c.resume_by_tag("nightly").unwrap();
    assert_eq!(info.manifest, manifest_id);
    assert_eq!(info.entries.len(), acked_spans.len());
    for (entry, acked) in info.entries.iter().zip(&acked_spans) {
        assert_eq!(entry.index, acked.index);
        assert_eq!(entry.first, acked.first, "replay reassigned an acked id");
        assert_eq!(entry.count, acked.count);
    }
    // Nothing settled pre-crash, so every entry is pending; wait each out
    // through the per-entry form (no job ids needed client-side).
    let pending: Vec<u32> = info.pending_entries().map(|e| e.index).collect();
    assert_eq!(pending.len(), info.entries.len());
    for idx in pending {
        let w = c.wait_entry(info.manifest, idx, 30.0).unwrap();
        assert!(!w.timed_out, "entry {idx} never dispatched after recovery");
        assert_eq!(w.dispatched, 1);
    }
    // Exactly-once collection: a second resume has nothing left pending.
    let again = c.resume_by_manifest(manifest_id).unwrap();
    assert_eq!(again.pending_entries().count(), 0);
    daemon.shutdown();
    handle.join().unwrap();
}
