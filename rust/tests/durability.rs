//! Durability end to end: the crash-point matrix over the fault-injection
//! harness (at **both** shard counts), torn-tail healing, the torn
//! allocator log, the cross-shard mid-manifest crash, a truncation fuzz
//! sweep over the recovery scanner, and the kill → recover → `RESUME`
//! workflow over real TCP.
//!
//! The contract under test (see PROTOCOL.md §Durability):
//!
//! * **No acked loss** — a submission the client saw an `OK` for exists
//!   after recovery, whatever the crash point or shard count.
//! * **No unacked resurrection under `fsync=always`** — a submission that
//!   failed before its record was durable is *gone* after recovery. In
//!   sharded layouts this extends to whole id-range leases: a cross-shard
//!   manifest whose parts did not all land is dropped atomically.
//! * **At-least-once edge** — a crash after the fsync but before the ack
//!   resurrects work the client never saw acked; `RESUME` is the
//!   idempotency tool.
//! * A torn final record (crash mid-write) truncates; it is never fatal —
//!   at any byte boundary, in any shard's journal, and in `alloc.log`.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::journal::JournalRecord;
use spotcloud::coordinator::{
    Client, Daemon, DaemonConfig, DurabilityConfig, ErrorCode, FaultPoint, FsyncPolicy, Journal,
    ManifestBuilder, Request, Response, RetryPolicy, Server, SqueueFilter, SubmitSpec,
};
use spotcloud::job::{JobType, QosClass};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use spotcloud::testkit::crash::TempDir;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// The shard counts every crash-matrix case runs at: the flat layout and
/// the smallest genuinely sharded one (per-shard journals + alloc.log).
const SHARD_COUNTS: [usize; 2] = [1, 2];

fn sched_cfg() -> SchedulerConfig {
    SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
}

/// A journaling daemon whose virtual clock is frozen (`speedup: 0`):
/// admitted jobs stay pending forever, so "what survived the crash" is
/// exactly "what was admitted".
fn frozen_cfg(dcfg: DurabilityConfig, shards: usize) -> DaemonConfig {
    DaemonConfig {
        speedup: 0.0,
        pacer_tick_ms: 1,
        durability: Some(dcfg),
        shard_count: shards,
        ..DaemonConfig::default()
    }
}

/// Submit one spot array job; `Ok(first_id)` on ack, `Err(code)` on a
/// typed refusal.
fn submit_spot(d: &Daemon, tasks: u32) -> Result<u64, ErrorCode> {
    match d.handle(Request::Submit(SubmitSpec::new(
        QosClass::Spot,
        JobType::Array,
        tasks,
        9,
    ))) {
        Response::SubmitAck(a) => Ok(a.first),
        Response::Error(e) => Err(e.code),
        other => panic!("{other:?}"),
    }
}

fn job_count(d: &Daemon) -> usize {
    match d.handle(Request::Squeue(SqueueFilter::default())) {
        Response::Jobs(rows) => rows.len(),
        other => panic!("{other:?}"),
    }
}

/// Every `*.wal` segment under `root`, shard-layout-aware (flat layouts
/// keep segments in `root`, sharded ones under `root/shard-<i>/`).
fn all_segments(root: &Path) -> Vec<PathBuf> {
    let mut segs = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(rd) = std::fs::read_dir(&dir) else {
            continue;
        };
        for entry in rd.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "wal") {
                segs.push(p);
            }
        }
    }
    segs
}

#[test]
fn crash_before_fsync_loses_only_the_unacked_submission() {
    for shards in SHARD_COUNTS {
        let tmp = TempDir::new("spotcloud-dur-afterappend");
        let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
        let faults = dcfg.faults.clone();
        let cfg = frozen_cfg(dcfg, shards);
        let acked;
        {
            let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
            acked = submit_spot(&d, 8).expect("pre-crash submission acks");
            // Crash after the record is written but before the fsync: the
            // record is lost AND the client was never acked.
            faults.arm(FaultPoint::AfterAppend);
            let err = submit_spot(&d, 16).expect_err("faulted submission must not ack");
            assert_eq!(err, ErrorCode::ReadOnly, "shards={shards}");
            d.shutdown();
        }
        let (d, report) =
            Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
        // Exactly the acked admission replays — nothing resurrected.
        assert_eq!(report.admits_replayed, 1, "shards={shards}: {report}");
        assert_eq!(job_count(&d), 1, "shards={shards}");
        match d.handle(Request::Sjob(acked)) {
            Response::Job(_) => {}
            other => panic!("shards={shards}: acked job lost across recovery: {other:?}"),
        }
    }
}

#[test]
fn crash_after_fsync_resurrects_the_durable_unacked_submission() {
    for shards in SHARD_COUNTS {
        let tmp = TempDir::new("spotcloud-dur-afterfsync");
        let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
        let faults = dcfg.faults.clone();
        let cfg = frozen_cfg(dcfg, shards);
        {
            let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
            submit_spot(&d, 8).expect("pre-crash submission acks");
            // Crash after the record is durable but before the ack: the
            // documented at-least-once edge.
            faults.arm(FaultPoint::AfterFsync);
            let err = submit_spot(&d, 16).expect_err("the crash swallowed the ack");
            assert_eq!(err, ErrorCode::ReadOnly, "shards={shards}");
            d.shutdown();
        }
        let (d, report) =
            Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
        // Both records were durable, so both replay — the unacked one
        // resurrects (clients dedupe via RESUME, not via the journal).
        assert_eq!(report.admits_replayed, 2, "shards={shards}: {report}");
        assert_eq!(job_count(&d), 2, "shards={shards}");
    }
}

#[test]
fn crash_mid_checkpoint_falls_back_to_the_previous_segments() {
    for shards in SHARD_COUNTS {
        let tmp = TempDir::new("spotcloud-dur-midckpt");
        let dcfg = DurabilityConfig::new(tmp.path())
            .with_fsync(FsyncPolicy::Always)
            .with_checkpoint_every(2);
        let faults = dcfg.faults.clone();
        let cfg = frozen_cfg(dcfg, shards);
        let (a, b);
        {
            let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
            a = submit_spot(&d, 8).expect("first ack");
            // The second admission trips the checkpoint stride; the rotation
            // tears mid-write. The admission itself was already durable and
            // acked in the old segment (group commit syncs the deferred
            // tail before any rotation).
            faults.arm(FaultPoint::MidCheckpoint);
            b = submit_spot(&d, 16)
                .expect("second ack (checkpoint failure is not an admission failure)");
            // The poisoned journal degrades the daemon to read-only.
            assert_eq!(submit_spot(&d, 4), Err(ErrorCode::ReadOnly), "shards={shards}");
            d.shutdown();
        }
        let (d, report) =
            Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
        assert!(
            report.segments_discarded >= 1,
            "shards={shards}: the torn rotation segment must be discarded: {report}"
        );
        assert_eq!(report.admits_replayed, 2, "shards={shards}: {report}");
        assert_eq!(job_count(&d), 2, "shards={shards}");
        for id in [a, b] {
            match d.handle(Request::Sjob(id)) {
                Response::Job(_) => {}
                other => panic!("shards={shards}: acked job {id} lost across recovery: {other:?}"),
            }
        }
    }
}

#[test]
fn torn_final_record_is_truncated_not_fatal() {
    for shards in SHARD_COUNTS {
        let tmp = TempDir::new("spotcloud-dur-torn");
        let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
        let cfg = frozen_cfg(dcfg, shards);
        let acked;
        {
            let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
            acked = submit_spot(&d, 8).expect("pre-crash submission acks");
            d.shutdown();
        }
        // A crash mid-write leaves a partial frame at the tail of the
        // newest segment; emulate it with garbage too short to even hold a
        // header. `all_segments` finds the shard-layout segment too.
        let newest = all_segments(tmp.path())
            .into_iter()
            .max()
            .expect("journal segment exists");
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&newest).unwrap();
        f.write_all(&[0xFF; 7]).unwrap();
        drop(f);
        let (d, report) =
            Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
        assert_eq!(report.torn_bytes, 7, "shards={shards}: {report}");
        match d.handle(Request::Sjob(acked)) {
            Response::Job(_) => {}
            other => panic!("shards={shards}: acked job lost to a torn tail: {other:?}"),
        }
    }
}

#[test]
fn torn_alloc_log_fails_the_admission_and_recovery_survives_it() {
    // The allocator log is the sharded layout's id authority: a crash
    // while appending a lease record must fail the admission unacked, and
    // recovery must replay everything acked before it — with fresh ids
    // provably past the torn lease.
    let tmp = TempDir::new("spotcloud-dur-allocappend");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let faults = dcfg.faults.clone();
    let cfg = frozen_cfg(dcfg, 2);
    let acked;
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        acked = submit_spot(&d, 8).expect("pre-crash submission acks");
        faults.arm(FaultPoint::AllocAppend);
        let err = submit_spot(&d, 16).expect_err("a torn lease record must not ack");
        assert_eq!(err, ErrorCode::ReadOnly);
        d.shutdown();
    }
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg)
        .expect("recovery survives a torn alloc.log");
    assert_eq!(report.admits_replayed, 1, "{report}");
    assert_eq!(job_count(&d), 1);
    match d.handle(Request::Sjob(acked)) {
        Response::Job(_) => {}
        other => panic!("acked job lost across recovery: {other:?}"),
    }
    // Post-recovery admissions allocate past everything ever leased —
    // including the torn lease — so ids never alias.
    let next = submit_spot(&d, 4).expect("post-recovery admission");
    assert!(next > acked, "fresh id {next} must clear the acked id {acked}");
}

#[test]
fn crash_between_shard_appends_drops_the_whole_cross_shard_lease() {
    // One manifest spanning both shards is one id-range lease with a part
    // in each shard journal. The shard-targeted fault lets shard 0's part
    // land and "crashes" shard 1's append — regardless of which shard the
    // scheduler appends first: the client is never acked, and recovery
    // must drop the lease *atomically* — replaying shard 0's part alone
    // would resurrect half a manifest.
    let tmp = TempDir::new("spotcloud-dur-xshard");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let faults = dcfg.faults.clone();
    let cfg = frozen_cfg(dcfg, 2);
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        submit_spot(&d, 8).expect("pre-crash submission acks");
        faults.arm_for_shard(1, FaultPoint::AfterAppend);
        let m = ManifestBuilder::new()
            .interactive(1, JobType::Array, 8)
            .spot(9, JobType::Array, 16)
            .build();
        match d.handle(Request::MSubmit(m)) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::ReadOnly),
            other => panic!("the half-journaled manifest must fail unacked: {other:?}"),
        }
        d.shutdown();
    }
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    assert_eq!(report.leases_skipped_torn, 1, "{report}");
    assert_eq!(
        report.admits_replayed, 1,
        "only the acked submission replays: {report}"
    );
    assert_eq!(report.manifests_restored, 0, "{report}");
    assert_eq!(job_count(&d), 1);
}

#[test]
fn every_truncation_prefix_of_a_segment_recovers_cleanly() {
    // A crash can land on any byte boundary. Sweep the recovery scanner
    // over every prefix of a real segment (plus a few bit flips): it must
    // never panic — each case either replays a prefix of the admissions or
    // fails with a typed error. This is the fuzz floor under every other
    // test in this file.
    let tmp = TempDir::new("spotcloud-dur-fuzz-src");
    let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
    let cfg = frozen_cfg(dcfg, 1);
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg);
        for _ in 0..3 {
            submit_spot(&d, 4).expect("ack");
        }
        d.shutdown();
    }
    let seg = all_segments(tmp.path())
        .into_iter()
        .max()
        .expect("journal segment exists");
    let name = seg.file_name().unwrap().to_owned();
    let bytes = std::fs::read(&seg).unwrap();
    let case = TempDir::new("spotcloud-dur-fuzz-case");
    let recovered_admits = |dir: &Path| -> Option<usize> {
        match Journal::recover(&DurabilityConfig::new(dir)) {
            Ok((_, rec)) => Some(
                rec.tail
                    .iter()
                    .filter(|r| matches!(r, JournalRecord::Admit { .. }))
                    .count(),
            ),
            // Typed failure (empty dir, torn magic, …) is a clean outcome
            // for a mangled journal; panicking is the only wrong answer.
            Err(_) => None,
        }
    };
    let mut last = 0usize;
    for cut in 0..=bytes.len() {
        std::fs::write(case.join(name.to_str().unwrap()), &bytes[..cut]).unwrap();
        if let Some(admits) = recovered_admits(case.path()) {
            assert!(admits <= 3, "cut={cut}: {admits} admissions from thin air");
            // Longer prefixes only ever complete more frames.
            assert!(admits >= last, "cut={cut}: replay went backwards");
            last = admits;
        }
    }
    assert_eq!(last, 3, "the full segment replays every admission");
    // Bit flips inside frames must fail the checksum, not fabricate state.
    for off in [8usize, 12, 20, bytes.len() - 1] {
        let mut mangled = bytes.clone();
        mangled[off] ^= 0x40;
        std::fs::write(case.join(name.to_str().unwrap()), &mangled).unwrap();
        if let Some(admits) = recovered_admits(case.path()) {
            assert!(admits < 3, "off={off}: a flipped bit passed the crc");
        }
    }
}

#[test]
fn tcp_kill_recover_resume_collects_exactly_the_unsettled_entries() {
    // The acceptance workflow end to end: a client submits a tagged
    // manifest, the daemon "crashes" before anything dispatches, a new
    // daemon recovers from the journal, and the client re-attaches with
    // retry/backoff + RESUME, waiting out exactly the entries that had not
    // settled. Runs at both shard counts: the sharded pass exercises the
    // per-shard journals + allocator log behind the same wire contract.
    for shards in SHARD_COUNTS {
        let tmp = TempDir::new("spotcloud-dur-tcp");
        let dcfg = DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always);
        let cfg = frozen_cfg(dcfg, shards); // frozen: nothing settles pre-crash
        let (manifest_id, acked_spans);
        {
            let daemon = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
            let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
            let addr = server.local_addr().unwrap().to_string();
            let handle = std::thread::spawn(move || server.serve());
            let mut c = Client::connect_v2(&addr).unwrap();
            let m = ManifestBuilder::new()
                .interactive(1, JobType::TripleMode, 608)
                .last(|e| e.with_tag("nightly"))
                .interactive(2, JobType::TripleMode, 608)
                .build();
            let ack = c.msubmit(&m).unwrap();
            manifest_id = ack.manifest.expect("a journaling daemon assigns manifest ids");
            acked_spans = ack.accepted.clone();
            daemon.shutdown(); // kill: no drain, no goodbye
            handle.join().unwrap();
        }
        // Recover on the same journal — this time with a live clock.
        let cfg = DaemonConfig {
            speedup: 10_000.0,
            ..cfg
        };
        let (daemon, report) =
            Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
        assert_eq!(report.manifests_restored, 1, "shards={shards}: {report}");
        daemon.spawn_pacer();
        let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve());
        // The resuming client reconnects with backoff, then re-attaches by
        // tag.
        let mut c = Client::connect_v2_retry(&addr, &RetryPolicy::default()).unwrap();
        let info = c.resume_by_tag("nightly").unwrap();
        assert_eq!(info.manifest, manifest_id);
        assert_eq!(info.entries.len(), acked_spans.len());
        for (entry, acked) in info.entries.iter().zip(&acked_spans) {
            assert_eq!(entry.index, acked.index);
            assert_eq!(entry.first, acked.first, "replay reassigned an acked id");
            assert_eq!(entry.count, acked.count);
        }
        // Nothing settled pre-crash, so every entry is pending; wait each
        // out through the per-entry form (no job ids needed client-side).
        let pending: Vec<u32> = info.pending_entries().map(|e| e.index).collect();
        assert_eq!(pending.len(), info.entries.len());
        for idx in pending {
            let w = c.wait_entry(info.manifest, idx, 30.0).unwrap();
            assert!(!w.timed_out, "entry {idx} never dispatched after recovery");
            assert_eq!(w.dispatched, 1);
        }
        // Exactly-once collection: a second resume has nothing left
        // pending.
        let again = c.resume_by_manifest(manifest_id).unwrap();
        assert_eq!(again.pending_entries().count(), 0);
        daemon.shutdown();
        handle.join().unwrap();
    }
}
