//! Failure injection: drained nodes, cancellations at every lifecycle stage,
//! rejected preemption modes, and pathological workloads.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::job::{JobSpec, JobState, JobType, UserId};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::{Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};

fn sched() -> Scheduler {
    Scheduler::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
    )
}

#[test]
fn cancel_pending_running_and_requeued_jobs() {
    let mut s = sched();
    // Pending cancel.
    let filler = s.submit(
        JobSpec::interactive(UserId(2), JobType::Array, 608).with_run_time(SimTime::from_secs(500)),
    );
    assert!(s.run_until_dispatched(&[filler], SimTime::from_secs(120)));
    let blocked = s.submit(JobSpec::interactive(UserId(1), JobType::Array, 64));
    s.run_for(SimTime::from_secs(30));
    assert_eq!(s.job(blocked).unwrap().state, JobState::Pending);
    assert!(s.cancel(blocked));
    assert_eq!(s.job(blocked).unwrap().state, JobState::Cancelled);

    // Running cancel frees resources.
    assert!(s.cancel(filler));
    assert_eq!(s.cluster().idle_cores(), 608);
    s.check_invariants().unwrap();

    // Double cancel fails gracefully.
    assert!(!s.cancel(filler));
    // Unknown job id fails gracefully.
    assert!(!s.cancel(spotcloud::job::JobId(999_999)));
}

#[test]
fn cancel_requeued_spot_job_before_it_restarts() {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Requeue,
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
    let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[j], SimTime::from_secs(600)));
    // The spot job is requeued (pending, held). Cancel it before restart.
    let st = s.job(spot).unwrap().state;
    assert!(matches!(st, JobState::Requeued | JobState::Pending), "{st:?}");
    assert!(s.cancel(spot));
    assert_eq!(s.job(spot).unwrap().state, JobState::Cancelled);
    s.run_for(SimTime::from_secs(7200));
    assert_eq!(
        s.job(spot).unwrap().state,
        JobState::Cancelled,
        "cancelled job must never restart"
    );
    s.check_invariants().unwrap();
}

#[test]
#[should_panic(expected = "GANG")]
fn gang_mode_is_rejected_by_the_engine() {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Gang,
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
    let _j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    s.run_for(SimTime::from_secs(600));
}

#[test]
fn zero_spot_cluster_cron_agent_is_a_noop() {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(160)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    s.run_for(SimTime::from_secs(600));
    assert!(s.stats().cron_passes >= 9, "agent keeps ticking");
    assert_eq!(s.stats().preemptions, 0);
    assert_eq!(s.cluster().idle_cores(), 608);
}

#[test]
fn burst_larger_than_cluster_dispatches_in_waves() {
    let mut s = sched();
    // 1216 one-core jobs on a 608-core cluster with short run times.
    let ids = s.submit_burst(
        (0..1216)
            .map(|_| {
                JobSpec::interactive(UserId(1), JobType::Individual, 1)
                    .with_run_time(SimTime::from_secs(60))
            })
            .collect(),
    );
    assert!(
        s.run_until_dispatched(&ids, SimTime::from_secs(4 * 3600)),
        "all waves must eventually dispatch"
    );
    s.check_invariants().unwrap();
}

#[test]
fn impossible_job_stays_pending_forever() {
    let mut s = sched();
    // 20 whole nodes on a 19-node cluster (within the user core limit of
    // 4096): the scheduler must neither dispatch nor wedge.
    let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 640));
    s.run_for(SimTime::from_secs(7200));
    assert_eq!(s.job(j).unwrap().state, JobState::Pending);
    // Other work continues to flow around it (backfill semantics).
    let ok = s.submit(JobSpec::interactive(UserId(2), JobType::Array, 32));
    assert!(
        s.run_until_dispatched(&[ok], SimTime::from_secs(7200)),
        "a blocked head-of-line job must not starve backfillable work forever"
    );
}

#[test]
fn drained_node_is_never_scheduled() {
    let mut s = sched();
    // Drain node 0 via the cluster API, then fill the cluster.
    s.cluster_mut_for_tests(|c| c.node_mut_for_tests(0).set_drained(true));
    let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    s.run_for(SimTime::from_secs(600));
    // 19 nodes needed, 18 available: must stay pending.
    assert_eq!(s.job(j).unwrap().state, JobState::Pending);
    // An 18-node job fits.
    let ok = s.submit(JobSpec::interactive(UserId(2), JobType::TripleMode, 576));
    assert!(s.run_until_dispatched(&[ok], SimTime::from_secs(600)));
    s.check_invariants().unwrap();
}
