//! Cross-module integration tests: scheduler + preemption engines + runtime
//! + workload generators composing as the paper's system does.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::job::{JobSpec, JobState, JobType, UserId};
use spotcloud::preempt::{manual, CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::{LogKind, Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};
use spotcloud::workload::{interactive_burst, spot_fill};

fn horizon() -> SimTime {
    SimTime::from_secs(4 * 3600)
}

/// The paper's headline, end to end: on the same spot-saturated cluster, the
/// cron-agent approach schedules an interactive job orders of magnitude
/// faster than scheduler-automatic preemption, and close to baseline.
#[test]
fn headline_cron_beats_auto_by_orders_of_magnitude() {
    // Baseline.
    let mut b = Scheduler::new(
        topology::txgreen_reservation(),
        SchedulerConfig::baseline(SchedCosts::production(), PartitionLayout::Dual),
    );
    let jb = b.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 2048));
    assert!(b.run_until_dispatched(&[jb], horizon()));
    let baseline = b.log().measure(&[jb]).unwrap().total_secs;

    // Auto preemption on a full cluster.
    let mut a = Scheduler::new(
        topology::txgreen_reservation(),
        SchedulerConfig::baseline(SchedCosts::production(), PartitionLayout::Dual).with_approach(
            PreemptApproach::AutoScheduler {
                mode: PreemptMode::Requeue,
            },
        ),
    );
    let ids = a.submit_burst(spot_fill(UserId(9), 4096, 1));
    assert!(a.run_until_dispatched(&ids, horizon()));
    let ja = a.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 2048));
    assert!(a.run_until_dispatched(&[ja], horizon()));
    let auto = a.log().measure(&[ja]).unwrap().total_secs;

    // Cron agent with a reserve covering the job.
    let mut c = Scheduler::new(
        topology::txgreen_reservation(),
        SchedulerConfig::baseline(SchedCosts::production(), PartitionLayout::Dual)
            .with_user_limit(2048)
            .with_approach(PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig { reserve_nodes: 32 },
            }),
    );
    let ids = c.submit_burst(spot_fill(UserId(9), 2048, 4));
    assert!(c.run_until_dispatched(&ids, horizon()));
    c.run_for(SimTime::from_secs(120));
    let jc = c.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 2048));
    assert!(c.run_until_dispatched(&[jc], horizon()));
    let cron = c.log().measure(&[jc]).unwrap().total_secs;

    // The paper's claim: "100 times faster performance in the scheduling of
    // a job with preemption" vs scheduler-automatic preemption.
    assert!(
        auto / cron >= 100.0,
        "cron ({cron:.2}s) must be >=100x faster than auto ({auto:.2}s)"
    );
    assert!(
        cron <= baseline * 3.0,
        "cron ({cron:.2}s) must be comparable to baseline ({baseline:.2}s)"
    );
}

/// All three approaches leave the scheduler in a consistent state, and the
/// spot job survives (requeued → running again) under REQUEUE.
#[test]
fn spot_job_lifecycle_through_all_approaches() {
    for approach in [
        PreemptApproach::AutoScheduler {
            mode: PreemptMode::Requeue,
        },
        PreemptApproach::Manual {
            mode: PreemptMode::Requeue,
        },
        PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes: 5 },
        },
    ] {
        let is_cron = matches!(approach, PreemptApproach::CronAgent { .. });
        let is_manual = matches!(approach, PreemptApproach::Manual { .. });
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(160)
            .with_approach(approach.clone());
        let mut s = Scheduler::new(topology::tx2500(), cfg);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, if is_cron { 448 } else { 608 }));
        assert!(s.run_until_dispatched(&[spot], horizon()), "{approach:?}");

        let burst = interactive_burst(UserId(1), JobType::TripleMode, 160);
        let jobs = if is_manual {
            manual::manual_submit(&mut s, burst, PreemptMode::Requeue).jobs
        } else {
            s.submit_burst(burst)
        };
        assert!(s.run_until_dispatched(&jobs, horizon()), "{approach:?}");
        s.check_invariants().unwrap();

        // After the interactive job completes, the spot job must end up
        // running again (requeue semantics) — possibly after the hold.
        s.run_for(SimTime::from_secs(3 * 3600 + 1800));
        assert_eq!(
            s.job(spot).unwrap().state,
            JobState::Running,
            "spot must recover under {}",
            approach.label()
        );
        s.check_invariants().unwrap();
    }
}

/// CANCEL mode kills the spot job for good — the usability reason the paper
/// picks REQUEUE.
#[test]
fn cancel_mode_loses_spot_work() {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Cancel,
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[spot], horizon()));
    let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[j], horizon()));
    s.run_for(SimTime::from_secs(24 * 3600));
    assert_eq!(s.job(spot).unwrap().state, JobState::Cancelled);
    assert_eq!(s.job(spot).unwrap().requeue_count, 0);
}

/// SUSPEND does not free memory: the interactive job stays blocked — why the
/// paper rejects SUSPEND.
#[test]
fn suspend_mode_blocks_interactive() {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Suspend,
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[spot], horizon()));
    let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    s.run_for(SimTime::from_secs(1800));
    assert_eq!(s.job(spot).unwrap().state, JobState::Suspended);
    assert_eq!(
        s.job(j).unwrap().state,
        JobState::Pending,
        "whole-memory interactive job cannot use suspended nodes"
    );
    s.check_invariants().unwrap();
}

/// Suspended spot jobs resume automatically once interactive demand clears
/// (the allocation was never released; only the state flips back).
#[test]
fn suspended_spot_resumes_when_demand_clears() {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Suspend,
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
    assert!(s.run_until_dispatched(&[spot], horizon()));
    let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
    s.run_for(SimTime::from_secs(600));
    assert_eq!(s.job(spot).unwrap().state, JobState::Suspended);
    assert_eq!(s.job(j).unwrap().state, JobState::Pending);
    // Cancel the interactive demand: the suspended job must resume on a
    // later pass, and must NOT be completed early by its stale JobEnd.
    assert!(s.cancel(j));
    s.run_for(SimTime::from_secs(120));
    assert_eq!(
        s.job(spot).unwrap().state,
        JobState::Running,
        "suspended spot job must resume once demand clears"
    );
    s.check_invariants().unwrap();
}

/// Fairshare: with equal-priority pending jobs, the user already holding
/// cores sorts after the idle user.
#[test]
fn fairshare_orders_heavy_user_later() {
    let mut s = Scheduler::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(1_000_000),
    );
    // User 1 grabs most of the cluster.
    let hog = s.submit(
        JobSpec::interactive(UserId(1), JobType::Array, 576).with_run_time(SimTime::from_secs(600)),
    );
    assert!(s.run_until_dispatched(&[hog], horizon()));
    // Fill the rest so the next two jobs queue up.
    let filler = s.submit(
        JobSpec::interactive(UserId(3), JobType::Array, 32).with_run_time(SimTime::from_secs(120)),
    );
    assert!(s.run_until_dispatched(&[filler], horizon()));
    // Two identical 32-core jobs: heavy user 1 first, light user 2 second.
    let heavy = s.submit(JobSpec::interactive(UserId(1), JobType::Array, 32));
    let light = s.submit(JobSpec::interactive(UserId(2), JobType::Array, 32));
    assert!(s.run_until_dispatched(&[heavy, light], horizon()));
    let t_heavy = s.log().last(heavy, LogKind::DispatchDone).unwrap();
    let t_light = s.log().last(light, LogKind::DispatchDone).unwrap();
    assert!(
        t_light < t_heavy,
        "light user ({t_light:?}) must dispatch before the hog ({t_heavy:?}) despite submitting later"
    );
}

/// The XLA scorer and the native scorer produce identical scheduling
/// decisions end to end (same dispatch order on a contended queue).
#[test]
fn xla_scorer_matches_native_end_to_end() {
    let Some(accel) = spotcloud::runtime::SchedAccel::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run = |cfg: SchedulerConfig| {
        let mut s = Scheduler::new(topology::tx2500(), cfg);
        // Occupy the cluster, then queue a contended mix.
        let big = s.submit(
            JobSpec::interactive(UserId(7), JobType::Array, 608)
                .with_run_time(SimTime::from_secs(600)),
        );
        assert!(s.run_until_dispatched(&[big], horizon()));
        let mut ids = Vec::new();
        for i in 0..20u32 {
            let user = UserId(1 + i % 4);
            let spec = if i % 3 == 0 {
                JobSpec::spot(user, JobType::Array, 32 + i)
            } else {
                JobSpec::interactive(user, JobType::Array, 16 + i)
            };
            ids.push(s.submit(spec));
        }
        s.run_for(SimTime::from_secs(3600));
        // Record dispatch order.
        let mut order: Vec<(SimTime, u64)> = ids
            .iter()
            .filter_map(|&id| s.log().first(id, LogKind::DispatchDone).map(|t| (t, id.0)))
            .collect();
        order.sort();
        order
    };
    let base = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(1_000_000);
    let native_order = run(base.clone());
    let xla_order = run(base.with_scorer(std::sync::Arc::new(accel)));
    assert_eq!(native_order, xla_order, "scorers must agree on dispatch order");
}

/// EASY backfill: a short job may leapfrog the blocked head; a long job
/// that would delay the head's shadow-reserved start may not.
#[test]
fn backfill_respects_shadow_reservation() {
    let mut s = Scheduler::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(1_000_000),
    );
    // Occupy 18 of 19 nodes until t≈1000s.
    let long = s.submit(
        JobSpec::interactive(UserId(1), JobType::TripleMode, 576)
            .with_run_time(SimTime::from_secs(1000)),
    );
    assert!(s.run_until_dispatched(&[long], horizon()));
    // Head job needs all 19 nodes: blocked, shadow ≈ t=1000s.
    let head = s.submit(JobSpec::interactive(UserId(2), JobType::TripleMode, 608));
    s.run_for(SimTime::from_secs(40));
    assert_eq!(s.job(head).unwrap().state, JobState::Pending);
    // Short filler (60s) fits before the shadow: backfill may run it.
    let short = s.submit(
        JobSpec::interactive(UserId(3), JobType::TripleMode, 32)
            .with_run_time(SimTime::from_secs(60)),
    );
    // Long filler (5000s) would push the head past its reservation: must wait.
    let hog = s.submit(
        JobSpec::interactive(UserId(4), JobType::TripleMode, 32)
            .with_run_time(SimTime::from_secs(5000)),
    );
    s.run_for(SimTime::from_secs(200));
    assert!(
        matches!(
            s.job(short).unwrap().state,
            JobState::Running | JobState::Completed
        ),
        "short filler must backfill into the idle node (got {:?})",
        s.job(short).unwrap().state
    );
    assert_eq!(
        s.job(hog).unwrap().state,
        JobState::Pending,
        "long filler would delay the reserved head start"
    );
    // The head eventually runs when the long job ends.
    assert!(s.run_until_dispatched(&[head], horizon()));
    s.check_invariants().unwrap();
}

/// Workload trace roundtrip drives the scheduler identically.
#[test]
fn trace_replay_is_deterministic() {
    use spotcloud::workload::{Trace, TraceRecord};
    let trace = Trace {
        records: vec![
            TraceRecord {
                at_secs: 1.0,
                user: 1,
                job_type: JobType::TripleMode,
                tasks: 320,
                qos: spotcloud::job::QosClass::Normal,
                run_secs: 300.0,
            },
            TraceRecord {
                at_secs: 30.0,
                user: 2,
                job_type: JobType::Array,
                tasks: 128,
                qos: spotcloud::job::QosClass::Spot,
                run_secs: 86_400.0,
            },
        ],
    };
    let replay = |t: &Trace| {
        let mut s = Scheduler::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        );
        let mut ids = Vec::new();
        for r in &t.records {
            s.run_until(SimTime::from_secs_f64(r.at_secs));
            ids.push(s.submit(r.to_spec()));
        }
        s.run_for(SimTime::from_secs(3600));
        ids.iter()
            .map(|&id| s.log().last(id, LogKind::DispatchDone))
            .collect::<Vec<_>>()
    };
    let roundtripped = Trace::from_csv(&trace.to_csv()).unwrap();
    assert_eq!(replay(&trace), replay(&roundtripped));
}
