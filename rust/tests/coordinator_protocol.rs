//! Integration tests for the versioned coordinator protocol: concurrent
//! v1/v2 clients against one server, atomic batch submission, and the
//! remote launch-latency measurement (`WAIT`).

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{
    Client, Daemon, DaemonConfig, ErrorCode, Server, SqueueFilter, SubmitSpec,
};
use spotcloud::coordinator::{ClientError, ProtocolVersion};
use spotcloud::job::{JobType, QosClass};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use std::sync::Arc;

fn spawn_server(workers: usize) -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let daemon = Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            // At 10k× speedup the default grace is well under a wall
            // second; keep retirement out of these protocol tests so
            // listing/wait assertions are not wall-timing coupled.
            retire_grace_secs: Some(86_400.0),
            ..DaemonConfig::default()
        },
    );
    daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", workers).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

/// ≥4 simultaneous connections, mixed v1/v2, doing submits + cancels +
/// waits; scheduler invariants must hold afterwards.
#[test]
fn concurrent_mixed_protocol_clients() {
    let (daemon, addr, server) = spawn_server(8);
    let mut threads = Vec::new();
    // Three typed v2 clients.
    for t in 0..3u32 {
        let a = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = Client::connect_v2(&a).unwrap();
            assert_eq!(c.version(), ProtocolVersion::V2);
            let user = 1 + t;
            let ack = c
                .submit(
                    &SubmitSpec::new(QosClass::Normal, JobType::Array, 32, user)
                        .with_run_secs(30.0),
                )
                .unwrap();
            let ids: Vec<u64> = ack.ids().collect();
            let w = c.wait(&ids, 30.0).unwrap();
            assert!(!w.timed_out, "jobs never dispatched: {w:?}");
            assert!(w.latency_ns > 0);
            // A second submission, cancelled while (possibly) pending: both
            // outcomes are legal, but the error must be typed if it fails.
            let ack2 = c
                .submit(
                    &SubmitSpec::new(QosClass::Normal, JobType::Array, 16, user)
                        .with_run_secs(600.0),
                )
                .unwrap();
            match c.cancel(ack2.first) {
                Ok(id) => assert_eq!(id, ack2.first),
                Err(ClientError::Api(e)) => assert_eq!(e.code, ErrorCode::NotFound),
                Err(other) => panic!("unexpected cancel failure: {other}"),
            }
            c.ping().unwrap();
        }));
    }
    // Three raw v1 clients exercising the seed grammar verbatim.
    for t in 0..3u32 {
        let a = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = Client::connect(&a).unwrap();
            let user = 10 + t;
            let r = c.request(&format!("SUBMIT spot triple 96 {user} 600")).unwrap();
            assert!(r.starts_with("OK jobs="), "{r}");
            assert_eq!(c.request("PING").unwrap(), "OK pong");
            let q = c.request("SQUEUE").unwrap();
            assert!(q.contains("JOBID"), "{q}");
            let id: u64 = r
                .split("jobs=")
                .nth(1)
                .unwrap()
                .split('-')
                .next()
                .unwrap()
                .parse()
                .unwrap();
            let out = c.request(&format!("SCANCEL {id}")).unwrap();
            assert!(out.starts_with("OK") || out.starts_with("ERR"), "{out}");
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    daemon.with_scheduler(|s| s.check_invariants().expect("scheduler invariants"));
    daemon.shutdown();
    server.join().unwrap();
}

/// A batched SUBMIT of 10,000 individual jobs completes in ONE RPC round
/// trip, and WAIT observes the launch latency remotely.
#[test]
fn batch_submit_10k_jobs_one_rpc() {
    let (daemon, addr, server) = spawn_server(2);
    let mut c = Client::connect_v2(&addr).unwrap();
    let ack = c
        .submit(
            &SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 7)
                .with_run_secs(30.0)
                .with_count(10_000),
        )
        .expect("one round trip must create the whole batch");
    assert_eq!(ack.count, 10_000);
    assert_eq!(ack.last - ack.first + 1, 10_000);
    // The daemon saw exactly one SUBMIT request.
    let stats = c.stats().unwrap();
    assert_eq!(stats.commands.get("submit").copied(), Some(1));
    assert_eq!(stats.jobs_submitted, 10_000);
    // SQUEUE truncation keeps the listing bounded.
    let rows = c
        .squeue(&SqueueFilter {
            limit: Some(100),
            ..Default::default()
        })
        .unwrap();
    assert_eq!(rows.len(), 100);
    // Remote launch-latency measurement on a sample of the burst.
    let sample = [ack.first, ack.first + 4_999, ack.last];
    let w = c.wait(&sample, 120.0).unwrap();
    assert!(!w.timed_out, "batch never fully dispatched: {w:?}");
    assert_eq!(w.dispatched, 3);
    assert!(w.latency_ns > 0);
    daemon.with_scheduler(|s| s.check_invariants().expect("scheduler invariants"));
    daemon.shutdown();
    server.join().unwrap();
}

/// The STATS v2 contention extension crosses the wire: a v2 client sees the
/// lock-path counters, a v1 client's STATS line keeps the original key set.
#[test]
fn stats_contention_extension_over_tcp() {
    let (daemon, addr, server) = spawn_server(2);
    let mut v2 = Client::connect_v2(&addr).unwrap();
    // Generate some write- and read-path traffic first.
    let ack = v2
        .submit(&SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 320, 9).with_run_secs(600.0))
        .unwrap();
    assert!(ack.count >= 1);
    v2.squeue(&SqueueFilter::default()).unwrap();
    let stats = v2.stats().unwrap();
    let c = stats
        .contention
        .expect("v2 STATS must carry the contention extension");
    // The pacer thread keeps taking the write lock, so only lower bounds
    // are race-free here (the exact count==histogram identity is asserted
    // in the pacer-less daemon unit test).
    assert!(c.write_locks >= 1, "{c:?}");
    assert!(c.read_path_ops >= 1, "{c:?}");
    assert!(c.lock_hold_count >= 1, "{c:?}");
    assert!(c.lock_hold_max_ns >= c.lock_hold_p50_ns, "{c:?}");
    // A raw v1 client on the same daemon: original key set, no extension.
    let mut v1 = Client::connect(&addr).unwrap();
    let line = v1.request("STATS").unwrap();
    assert!(line.contains("dispatches="), "{line}");
    assert!(!line.contains("read_path_ops="), "{line}");
    daemon.shutdown();
    server.join().unwrap();
}

/// A v1 session can upgrade mid-connection and keep working, and v1 lines
/// accepted at seed still work verbatim over TCP.
#[test]
fn mid_session_upgrade_and_seed_grammar() {
    let (daemon, addr, server) = spawn_server(2);
    let mut c = Client::connect(&addr).unwrap();
    // Seed grammar, verbatim.
    let r = c.request("SUBMIT normal triple 608 1 60").unwrap();
    assert!(r.starts_with("OK jobs="), "{r}");
    let u = c.request("UTIL").unwrap();
    assert!(u.contains("total_cores=608"), "{u}");
    // Upgrade the same connection.
    assert_eq!(c.hello(ProtocolVersion::V2).unwrap(), ProtocolVersion::V2);
    let util = c.util().unwrap();
    assert_eq!(util.total_cores, 608);
    // Typed error surfaces as Err, not Ok(String).
    match c.job(999_999) {
        Err(ClientError::Api(e)) => assert_eq!(e.code, ErrorCode::NotFound),
        other => panic!("expected typed NotFound, got {other:?}"),
    }
    daemon.shutdown();
    server.join().unwrap();
}
