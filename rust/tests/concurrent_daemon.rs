//! Concurrency stress for the coordinator core: reader threads hammer the
//! snapshot read path while writer threads submit/cancel bursts and waiter
//! threads block in `WAIT` — the contention regime the sharded-state
//! refactor exists for. The load itself is the shared
//! `benchkit::coordinator` harness (also the CI bench gate), so there is
//! one contention workload to maintain; the assertions here are the
//! correctness ones: scheduler invariants under fire (checked inside
//! `run_mixed_load`), every parked waiter waking exactly once (no lost
//! notify, no double-wake), no wait timeouts, and read-your-writes
//! visibility on the snapshot path.

use spotcloud::benchkit::coordinator::{run_mixed_load, MixedLoadConfig};
use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{Daemon, DaemonConfig, ProtocolVersion, Request, Response, SubmitSpec};
use spotcloud::job::{JobType, QosClass};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn daemon() -> Arc<Daemon> {
    Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        },
    )
}

/// N readers × M writers × K waiters against one daemon (the benchkit
/// mixed-load harness at a stress shape). `run_mixed_load` panics on any
/// ill-typed response and asserts `check_invariants()` after the run; on
/// top of that, the report must show real progress on all three thread
/// classes, zero wait timeouts, and balanced parked/resumed counters —
/// the exactly-once wake contract.
#[test]
fn readers_writers_waiters_stress() {
    let report = run_mixed_load(&MixedLoadConfig {
        readers: 6,
        writers: 3,
        waiters: 4,
        duration: Duration::from_millis(600),
        submit_batch: 16,
        writer_pause: Duration::from_millis(2),
        speedup: 10_000.0,
    });
    assert!(report.read_ops > 0, "{report:?}");
    assert!(report.write_ops > 0, "{report:?}");
    assert!(report.wait_ops > 0, "{report:?}");
    assert_eq!(report.timed_out_waits, 0, "wait timed out under stress");
    assert_eq!(
        report.waits_parked, report.waits_resumed,
        "parked/resumed imbalance: a waiter was lost or woken twice"
    );
    // Client reads are snapshot-served; the daemon-level counter includes
    // them all (internal WAIT polling is unmetered).
    assert!(report.read_path_ops >= report.read_ops);
}

/// Reads observe a mutation as soon as the mutating request returns: the
/// snapshot is published before the scheduler mutex is released.
#[test]
fn reads_observe_writes_immediately() {
    let d = daemon();
    let ack = match d.handle(Request::Submit(
        SubmitSpec::new(QosClass::Spot, JobType::Array, 8, 3).with_run_secs(600.0),
    )) {
        Response::SubmitAck(a) => a,
        other => panic!("{other:?}"),
    };
    // Same-thread read-your-writes.
    match d.handle(Request::Sjob(ack.first)) {
        Response::Job(detail) => assert_eq!(detail.user, 3),
        other => panic!("submitted job invisible to the read path: {other:?}"),
    }
    match d.handle(Request::Scancel(ack.first)) {
        Response::Cancelled(_) => {}
        other => panic!("{other:?}"),
    }
    match d.handle(Request::Sjob(ack.first)) {
        Response::Job(detail) => {
            assert!(detail.state.is_terminal(), "cancel invisible: {detail:?}")
        }
        other => panic!("{other:?}"),
    }
}

/// Empty WAIT regression over the wire (v2 `jobs=`): returns immediately
/// with dispatched=0 instead of blocking until the timeout.
#[test]
fn empty_wait_returns_immediately_over_the_wire() {
    let d = daemon();
    let t0 = Instant::now();
    let (resp, _) = d.handle_line_versioned("WAIT jobs= timeout=30", ProtocolVersion::V2);
    assert_eq!(
        resp,
        "OK kind=wait requested=0 dispatched=0 timed_out=false latency_ns=0"
    );
    assert!(t0.elapsed() < Duration::from_secs(5), "empty WAIT blocked");
}
