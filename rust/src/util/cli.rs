//! A small declarative command-line parser (clap is unavailable offline).
//!
//! Supports the patterns the `spotcloud` binary needs:
//!
//! * subcommands (`spotcloud experiment fig2a --seed 7`),
//! * long flags with values (`--seed 7`, `--seed=7`),
//! * boolean switches (`--verbose`),
//! * positional arguments, and
//! * auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without the leading `--`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// If true the option is a boolean switch and takes no value.
    pub switch: bool,
    /// Default value rendered in help (switches ignore this).
    pub default: Option<&'static str>,
}

/// Declarative command description used to parse an argument vector.
#[derive(Debug, Clone, Default)]
pub struct Command {
    /// Command name (for help output).
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Option specifications.
    pub opts: Vec<OptSpec>,
    /// Names of expected positional arguments, for help.
    pub positionals: Vec<(&'static str, &'static str)>,
}

/// Parse result: options and positionals.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    opts: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// Positional arguments in order.
    pub positionals: Vec<String>,
}

/// Errors produced while parsing.
#[derive(Debug, PartialEq, Eq)]
pub enum CliError {
    /// An option not in the command's spec.
    UnknownOption(String),
    /// A value-taking option with no value.
    MissingValue(String),
    /// A value that failed to parse.
    InvalidValue {
        /// Option name.
        name: String,
        /// Raw value.
        value: String,
        /// Parse failure description.
        reason: String,
    },
    /// `--help` / `-h` was given.
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::InvalidValue {
                name,
                value,
                reason,
            } => write!(f, "invalid value for --{name}: {value}: {reason}"),
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Command {
    /// Create a command with a name and description.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Add a value-taking option.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            switch: false,
            default,
        });
        self
    }

    /// Add a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            switch: true,
            default: None,
        });
        self
    }

    /// Document a positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Render `--help` output.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = write!(s, "\nusage: {}", self.name);
        for (p, _) in &self.positionals {
            let _ = write!(s, " <{p}>");
        }
        if !self.opts.is_empty() {
            let _ = write!(s, " [options]");
        }
        let _ = writeln!(s);
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\narguments:");
            for (p, h) in &self.positionals {
                let _ = writeln!(s, "  {p:<18} {h}");
            }
        }
        if !self.opts.is_empty() {
            let _ = writeln!(s, "\noptions:");
            for o in &self.opts {
                let name = if o.switch {
                    format!("--{}", o.name)
                } else {
                    format!("--{} <v>", o.name)
                };
                let def = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                let _ = writeln!(s, "  {name:<18} {}{def}", o.help);
            }
        }
        s
    }

    /// Parse an argument vector (not including the command name itself).
    pub fn parse<I, S>(&self, args: I) -> Result<Parsed, CliError>
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        let mut out = Parsed::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                out.opts.insert(o.name.to_string(), d.to_string());
            }
            if o.switch {
                out.switches.insert(o.name.to_string(), false);
            }
        }
        let args: Vec<String> = args.into_iter().map(|s| s.as_ref().to_string()).collect();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::UnknownOption(name.clone()))?;
                if spec.switch {
                    out.switches.insert(name, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    out.opts.insert(name, val);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Parsed {
    /// Raw string value of an option (default applies).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Boolean switch state.
    pub fn flag(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Parse an option value into any `FromStr` type.
    pub fn value<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name).ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse::<T>().map_err(|e| CliError::InvalidValue {
            name: name.to_string(),
            value: raw.to_string(),
            reason: e.to_string(),
        })
    }

    /// Like [`Parsed::value`] but returns `None` when the option was never
    /// given and has no default.
    pub fn value_opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|e| CliError::InvalidValue {
                    name: name.to_string(),
                    value: raw.to_string(),
                    reason: e.to_string(),
                }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "test command")
            .opt("seed", "rng seed", Some("42"))
            .opt("nodes", "node count", None)
            .switch("verbose", "chatty output")
            .positional("target", "what to run")
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(Vec::<String>::new()).unwrap();
        assert_eq!(p.value::<u64>("seed").unwrap(), 42);
        assert!(!p.flag("verbose"));
        assert!(p.get("nodes").is_none());
    }

    #[test]
    fn space_and_equals_forms() {
        let p = cmd().parse(["--seed", "7", "--nodes=19"]).unwrap();
        assert_eq!(p.value::<u64>("seed").unwrap(), 7);
        assert_eq!(p.value::<u32>("nodes").unwrap(), 19);
    }

    #[test]
    fn switches_and_positionals() {
        let p = cmd().parse(["fig2a", "--verbose", "extra"]).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals, vec!["fig2a", "extra"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert_eq!(
            cmd().parse(["--bogus"]).unwrap_err(),
            CliError::UnknownOption("bogus".into())
        );
    }

    #[test]
    fn missing_value_rejected() {
        assert_eq!(
            cmd().parse(["--nodes"]).unwrap_err(),
            CliError::MissingValue("nodes".into())
        );
    }

    #[test]
    fn bad_value_reported() {
        let err = cmd().parse(["--seed", "banana"]).unwrap().value::<u64>("seed");
        assert!(matches!(err, Err(CliError::InvalidValue { .. })));
    }

    #[test]
    fn help_flag() {
        assert_eq!(cmd().parse(["--help"]).unwrap_err(), CliError::HelpRequested);
        let h = cmd().help();
        assert!(h.contains("--seed"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("<target>"));
    }
}
