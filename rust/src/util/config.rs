//! `slurm.conf`-style configuration file parser.
//!
//! The paper configures preemption via `slurm.conf` parameters
//! (`PreemptMode`, `PreemptType`, `SchedulerParameters=preempt_youngest_first`,
//! QoS `MaxTRESPerUser`, …). We mirror that: a simple line-oriented
//! `Key=Value` format with `#` comments, repeated keys collected in order,
//! and typed accessors. Used by the daemon and the experiment harness so
//! cluster setups are file-describable like the real system.

use std::collections::BTreeMap;

/// Parsed configuration: ordered multimap of keys to values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConfigFile {
    entries: Vec<(String, String)>,
    index: BTreeMap<String, Vec<usize>>,
}

/// Errors produced while parsing or reading values.
#[derive(Debug)]
pub enum ConfigError {
    /// A line without `Key=Value` shape.
    Malformed {
        /// 1-based line number.
        line: usize,
        /// The offending raw line.
        text: String,
    },
    /// A required key was absent.
    Missing(String),
    /// A value failed to parse as the requested type.
    BadValue {
        /// The key whose value failed.
        key: String,
        /// The raw value.
        value: String,
        /// Target type name.
        ty: &'static str,
    },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Malformed { line, text } => {
                write!(f, "line {line}: expected Key=Value, got {text:?}")
            }
            ConfigError::Missing(key) => write!(f, "missing required key {key:?}"),
            ConfigError::BadValue { key, value, ty } => {
                write!(f, "key {key:?}: cannot parse {value:?} as {ty}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl ConfigFile {
    /// Parse the text of a config file.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = ConfigFile::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(pos) => &raw[..pos],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ConfigError::Malformed {
                line: lineno + 1,
                text: raw.to_string(),
            })?;
            cfg.push(k.trim(), v.trim());
        }
        Ok(cfg)
    }

    /// Load and parse a file from disk.
    pub fn load(path: &std::path::Path) -> crate::util::error::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    /// Append a key/value pair (keys are case-insensitive, stored lowered).
    pub fn push(&mut self, key: &str, value: &str) {
        let k = key.to_ascii_lowercase();
        let idx = self.entries.len();
        self.entries.push((k.clone(), value.to_string()));
        self.index.entry(k).or_default().push(idx);
    }

    /// Last value for a key (slurm semantics: later wins), if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        let k = key.to_ascii_lowercase();
        self.index
            .get(&k)
            .and_then(|v| v.last())
            .map(|&i| self.entries[i].1.as_str())
    }

    /// All values for a repeated key, in file order.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        let k = key.to_ascii_lowercase();
        self.index
            .get(&k)
            .map(|v| v.iter().map(|&i| self.entries[i].1.as_str()).collect())
            .unwrap_or_default()
    }

    /// Required string value.
    pub fn require(&self, key: &str) -> Result<&str, ConfigError> {
        self.get(key).ok_or_else(|| ConfigError::Missing(key.to_string()))
    }

    /// Typed value with a default when the key is absent.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse::<T>().map_err(|_| ConfigError::BadValue {
                key: key.to_string(),
                value: raw.to_string(),
                ty: std::any::type_name::<T>(),
            }),
        }
    }

    /// Boolean value: yes/no/true/false/1/0 (case-insensitive).
    pub fn get_bool_or(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => match raw.to_ascii_lowercase().as_str() {
                "yes" | "true" | "1" => Ok(true),
                "no" | "false" | "0" => Ok(false),
                _ => Err(ConfigError::BadValue {
                    key: key.to_string(),
                    value: raw.to_string(),
                    ty: "bool",
                }),
            },
        }
    }

    /// Parse a `SchedulerParameters`-style comma-separated option list.
    /// Returns the set of bare flags and `opt=value` pairs.
    pub fn option_list(&self, key: &str) -> (Vec<String>, BTreeMap<String, String>) {
        let mut flags = Vec::new();
        let mut kvs = BTreeMap::new();
        if let Some(raw) = self.get(key) {
            for part in raw.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                match part.split_once('=') {
                    Some((k, v)) => {
                        kvs.insert(k.to_ascii_lowercase(), v.to_string());
                    }
                    None => flags.push(part.to_ascii_lowercase()),
                }
            }
        }
        (flags, kvs)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entries were parsed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster definition
ClusterName=tx-2500
PreemptType=preempt/qos     # QoS based
PreemptMode=REQUEUE
SchedulerParameters=preempt_youngest_first,bf_interval=30
NodeName=n[01-19]
PartitionName=interactive
PartitionName=spot
"#;

    #[test]
    fn parses_and_reads() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get("clustername"), Some("tx-2500"));
        assert_eq!(cfg.get("PreemptMode"), Some("REQUEUE"));
        assert_eq!(cfg.get_all("PartitionName"), vec!["interactive", "spot"]);
    }

    #[test]
    fn comments_stripped_and_case_insensitive() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get("preempttype"), Some("preempt/qos"));
    }

    #[test]
    fn option_list_parses_flags_and_kvs() {
        let cfg = ConfigFile::parse(SAMPLE).unwrap();
        let (flags, kvs) = cfg.option_list("SchedulerParameters");
        assert!(flags.contains(&"preempt_youngest_first".to_string()));
        assert_eq!(kvs.get("bf_interval").map(String::as_str), Some("30"));
    }

    #[test]
    fn later_key_wins() {
        let cfg = ConfigFile::parse("A=1\nA=2\n").unwrap();
        assert_eq!(cfg.get("a"), Some("2"));
        assert_eq!(cfg.get_all("a"), vec!["1", "2"]);
    }

    #[test]
    fn malformed_line_errors() {
        let err = ConfigFile::parse("no equals sign here").unwrap_err();
        assert!(matches!(err, ConfigError::Malformed { line: 1, .. }));
    }

    #[test]
    fn typed_and_bool_accessors() {
        let cfg = ConfigFile::parse("Count=17\nEnable=yes\n").unwrap();
        assert_eq!(cfg.get_parsed_or::<u32>("Count", 0).unwrap(), 17);
        assert_eq!(cfg.get_parsed_or::<u32>("Absent", 5).unwrap(), 5);
        assert!(cfg.get_bool_or("Enable", false).unwrap());
        assert!(cfg.get_bool_or("Absent", true).unwrap());
        assert!(cfg.get_parsed_or::<u32>("Enable", 0).is_err());
    }
}
