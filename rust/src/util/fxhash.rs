//! FxHash (the rustc hasher), std-only.
//!
//! A fast, non-cryptographic, multiply-rotate hash for small keys. The
//! scheduler event-log indexes sit on the simulator hot path and SipHash was
//! 28% of burst-experiment time (EXPERIMENTS.md §Perf); this is the same
//! algorithm the `rustc-hash` crate ships, reimplemented here because the
//! offline build vendors no ecosystem crates.

use std::hash::{BuildHasherDefault, Hasher};

/// 64-bit Fx multiplier (from rustc / firefox).
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The Fx hasher state.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Word-at-a-time over the tail-padded input.
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_ne_bytes(c.try_into().expect("8-byte chunk")));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_ne_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed with Fx hashing (drop-in for `rustc_hash::FxHashMap`).
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// `HashSet` keyed with Fx hashing.
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = FxHasher::default();
        let mut b = FxHasher::default();
        a.write_u64(0xdead_beef);
        b.write_u64(0xdead_beef);
        assert_eq!(a.finish(), b.finish());
        assert_ne!(a.finish(), 0);
    }

    #[test]
    fn byte_slices_hash_by_content() {
        let mut a = FxHasher::default();
        let mut b = FxHasher::default();
        a.write(b"hello world, this is long enough to cross a chunk");
        b.write(b"hello world, this is long enough to cross a chunk");
        assert_eq!(a.finish(), b.finish());
        let mut c = FxHasher::default();
        c.write(b"hello world, this is long enough to cross a chunk!");
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn map_basics() {
        let mut m: FxHashMap<(u64, u8), u64> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert((i, (i % 7) as u8), i * 2);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&(500, (500 % 7) as u8)), Some(&1000));
    }
}
