//! ASCII table and number formatting for experiment reports.
//!
//! The benchmark harness prints the same rows/series the paper's figures
//! plot; this module renders them as aligned tables so the shape comparison
//! (who wins, by what factor) is readable in a terminal and in
//! EXPERIMENTS.md.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    /// Attach a title printed above the table.
    pub fn with_title<S: Into<String>>(mut self, title: S) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(widths[i] - c.chars().count() + 1));
                s.push('|');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (for plotting outside the terminal).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with an adaptive unit (ns/µs/ms/s), 3 significant digits.
pub fn fmt_seconds(s: f64) -> String {
    let a = s.abs();
    if a == 0.0 {
        "0 s".into()
    } else if a < 1e-6 {
        format!("{:.3} ns", s * 1e9)
    } else if a < 1e-3 {
        format!("{:.3} µs", s * 1e6)
    } else if a < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Format a ratio like `123x` / `0.5x` with sensible precision.
pub fn fmt_ratio(r: f64) -> String {
    if r >= 100.0 {
        format!("{r:.0}x")
    } else if r >= 10.0 {
        format!("{r:.1}x")
    } else {
        format!("{r:.2}x")
    }
}

/// Format a float in scientific notation with 2 decimals (like the paper's
/// log-scale axis labels).
pub fn fmt_sci(x: f64) -> String {
    format!("{x:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["job type", "sec/task"]).with_title("Fig 2a");
        t.row(vec!["individual", "1.2e-2"]);
        t.row(vec!["triple-mode", "1.2e-4"]);
        let s = t.render();
        assert!(s.contains("Fig 2a"));
        assert!(s.contains("| job type    |"));
        // Every data line has the same width.
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["has,comma", "has\"quote"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn second_formatting_units() {
        assert_eq!(fmt_seconds(0.0), "0 s");
        assert!(fmt_seconds(3.2e-9).ends_with("ns"));
        assert!(fmt_seconds(4.5e-5).ends_with("µs"));
        assert!(fmt_seconds(1.2e-2).ends_with("ms"));
        assert!(fmt_seconds(2.0).ends_with(" s"));
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(fmt_ratio(123.4), "123x");
        assert_eq!(fmt_ratio(12.34), "12.3x");
        assert_eq!(fmt_ratio(1.234), "1.23x");
    }
}
