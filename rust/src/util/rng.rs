//! Deterministic pseudo-random number generation.
//!
//! `rand` is not available offline, so this module implements the two
//! generators the project needs:
//!
//! * [`SplitMix64`] — used for seeding and cheap hashing-style streams.
//! * [`Xoshiro256`] — xoshiro256** 1.0, the workhorse generator for workload
//!   synthesis. Statistically strong, 4×64-bit state, sub-ns step.
//!
//! Plus the distributions used by the workload generators: uniform ranges,
//! exponential (Poisson inter-arrival times), log-normal (job durations), and
//! small-λ Poisson counts. Everything is deterministic given a seed so every
//! experiment in the paper harness is exactly reproducible.

/// SplitMix64 (Steele, Lea, Flood 2014). Primarily a seeder for
/// [`Xoshiro256`]; also handy as a tiny stateless stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna 2018).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors (avoids the
    /// all-zero state and correlated low-entropy seeds).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    ///
    /// Uses Lemire's nearly-divisionless bounded method.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range {lo}..{hi}");
        let span = hi - lo;
        // Lemire multiply-shift rejection.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(0, n as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponentially distributed sample with the given rate λ (mean 1/λ).
    /// Used for Poisson-process inter-arrival times.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential: rate must be positive");
        // Inverse CDF; guard u=0 so ln() stays finite.
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller (one value per call; no caching to keep
    /// the generator state trivially cloneable).
    pub fn normal(&mut self, mean: f64, stdev: f64) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + stdev * z
    }

    /// Log-normal sample: `exp(N(mu, sigma))`. Used for job durations.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson-distributed count (Knuth's method; fine for λ ≲ 30 which is
    /// all the workload generators use).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose: empty slice");
        &xs[self.index(xs.len())]
    }
}

/// Zipf-distributed ranks on `{1, …, n}` with `P(k) ∝ k^-s` — the
/// heavy-tail user-popularity shape the million-user scaling scenario
/// draws submitters from.
///
/// Uses Hörmann & Derflinger's rejection-inversion for monotone discrete
/// distributions: O(1) setup (no O(n) cumulative table, which matters at
/// n = 10⁶) and ~1 uniform per sample with a rejection rate bounded far
/// below 1 for every `s > 0`.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    s: f64,
    /// `H(1.5) - 1` — the upper end of the inversion interval.
    h_x1: f64,
    /// `H(n + 0.5)` — the lower end of the inversion interval.
    h_n: f64,
    /// Acceptance shortcut threshold (`2 - H⁻¹(H(2.5) - h(2))`).
    guard: f64,
}

impl Zipf {
    /// A sampler over ranks `1..=n` with exponent `s`. Panics on `n == 0`
    /// or a non-positive/non-finite exponent.
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "Zipf: n must be at least 1");
        assert!(s > 0.0 && s.is_finite(), "Zipf: exponent must be positive");
        let mut z = Self {
            n,
            s,
            h_x1: 0.0,
            h_n: 0.0,
            guard: 0.0,
        };
        z.h_x1 = z.h_integral(1.5) - 1.0;
        z.h_n = z.h_integral(n as f64 + 0.5);
        z.guard = 2.0 - z.h_integral_inverse(z.h_integral(2.5) - z.h(2.0));
        z
    }

    /// Number of ranks.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Draw one rank in `1..=n`.
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        loop {
            let u = self.h_n + rng.next_f64() * (self.h_x1 - self.h_n);
            let x = self.h_integral_inverse(u);
            // Round to the nearest rank and clamp into range (fp drift at
            // the interval ends can land a hair outside).
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.guard || u >= self.h_integral(k + 0.5) - self.h(k) {
                return k as u64;
            }
        }
    }

    /// `h(x) = x^-s`, the pmf kernel.
    fn h(&self, x: f64) -> f64 {
        (-self.s * x.ln()).exp()
    }

    /// `H(x) = ∫ x^-s dx = (x^(1-s) - 1)/(1-s)`, continuously extended
    /// through `s = 1` (where it is `ln x`) via `(e^y - 1)/y`.
    fn h_integral(&self, x: f64) -> f64 {
        let log_x = x.ln();
        expm1_over_x((1.0 - self.s) * log_x) * log_x
    }

    /// `H⁻¹(y) = (1 + y(1-s))^(1/(1-s))`, same continuous extension.
    fn h_integral_inverse(&self, y: f64) -> f64 {
        let mut t = y * (1.0 - self.s);
        // Guard fp drift past the pole so ln_1p stays defined.
        if t < -1.0 {
            t = -1.0;
        }
        (ln1p_over_x(t) * y).exp()
    }
}

/// `(e^x - 1)/x`, with the removable singularity at 0 filled in.
fn expm1_over_x(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.exp_m1() / x
    } else {
        // Two-term Taylor expansion: 1 + x/2 + O(x²).
        1.0 + x * 0.5
    }
}

/// `ln(1+x)/x`, with the removable singularity at 0 filled in.
fn ln1p_over_x(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.ln_1p() / x
    } else {
        1.0 - x * 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::new(99);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(5, 15);
            assert!((5..15).contains(&x));
            seen[(x - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should occur");
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Xoshiro256::new(11);
        let rate = 2.0;
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exponential(rate)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} should be ~0.5");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Xoshiro256::new(13);
        let lambda = 4.0;
        let n = 50_000;
        let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean {mean} should be ~{lambda}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.15);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn gen_range_empty_panics() {
        let mut r = Xoshiro256::new(1);
        r.gen_range(5, 5);
    }

    #[test]
    fn zipf_ranks_in_range_and_deterministic() {
        let z = Zipf::new(1_000_000, 1.1);
        let mut a = Xoshiro256::new(31);
        let mut b = Xoshiro256::new(31);
        let va: Vec<u64> = (0..1_000).map(|_| z.sample(&mut a)).collect();
        let vb: Vec<u64> = (0..1_000).map(|_| z.sample(&mut b)).collect();
        assert_eq!(va, vb, "same seed, same ranks");
        assert!(va.iter().all(|&k| (1..=1_000_000).contains(&k)));
        assert!(va.iter().any(|&k| k > 1_000), "tail ranks should appear");
    }

    #[test]
    fn zipf_degenerate_n1_always_one() {
        let z = Zipf::new(1, 2.0);
        let mut r = Xoshiro256::new(5);
        assert!((0..1_000).all(|_| z.sample(&mut r) == 1));
    }

    #[test]
    fn zipf_matches_exact_head_probabilities() {
        let n = 100u64;
        let s = 1.1;
        let z = Zipf::new(n, s);
        let mut r = Xoshiro256::new(77);
        let samples = 200_000;
        let mut counts = vec![0u64; n as usize + 1];
        for _ in 0..samples {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // Exact pmf from the normalizing harmonic sum.
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        for k in [1u64, 2, 3, 10] {
            let want = (k as f64).powf(-s) / h;
            let got = counts[k as usize] as f64 / samples as f64;
            assert!(
                (got - want).abs() < 0.01,
                "rank {k}: got {got:.4}, want {want:.4}"
            );
        }
        assert!(counts[1] > counts[2] && counts[2] > counts[10], "head-heavy");
    }
}
