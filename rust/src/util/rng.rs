//! Deterministic pseudo-random number generation.
//!
//! `rand` is not available offline, so this module implements the two
//! generators the project needs:
//!
//! * [`SplitMix64`] — used for seeding and cheap hashing-style streams.
//! * [`Xoshiro256`] — xoshiro256** 1.0, the workhorse generator for workload
//!   synthesis. Statistically strong, 4×64-bit state, sub-ns step.
//!
//! Plus the distributions used by the workload generators: uniform ranges,
//! exponential (Poisson inter-arrival times), log-normal (job durations), and
//! small-λ Poisson counts. Everything is deterministic given a seed so every
//! experiment in the paper harness is exactly reproducible.

/// SplitMix64 (Steele, Lea, Flood 2014). Primarily a seeder for
/// [`Xoshiro256`]; also handy as a tiny stateless stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna 2018).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors (avoids the
    /// all-zero state and correlated low-entropy seeds).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    ///
    /// Uses Lemire's nearly-divisionless bounded method.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range {lo}..{hi}");
        let span = hi - lo;
        // Lemire multiply-shift rejection.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(0, n as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponentially distributed sample with the given rate λ (mean 1/λ).
    /// Used for Poisson-process inter-arrival times.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential: rate must be positive");
        // Inverse CDF; guard u=0 so ln() stays finite.
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller (one value per call; no caching to keep
    /// the generator state trivially cloneable).
    pub fn normal(&mut self, mean: f64, stdev: f64) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + stdev * z
    }

    /// Log-normal sample: `exp(N(mu, sigma))`. Used for job durations.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson-distributed count (Knuth's method; fine for λ ≲ 30 which is
    /// all the workload generators use).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose: empty slice");
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::new(99);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(5, 15);
            assert!((5..15).contains(&x));
            seen[(x - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should occur");
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Xoshiro256::new(11);
        let rate = 2.0;
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exponential(rate)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} should be ~0.5");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Xoshiro256::new(13);
        let lambda = 4.0;
        let n = 50_000;
        let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean {mean} should be ~{lambda}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.15);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn gen_range_empty_panics() {
        let mut r = Xoshiro256::new(1);
        r.gen_range(5, 5);
    }
}
