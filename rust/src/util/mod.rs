//! Std-only utility substrates.
//!
//! The offline build environment vendors only the crates needed by the XLA
//! bridge, so the usual ecosystem crates (`rand`, `clap`, `serde`, …) are not
//! available. These modules provide the subsets we need, built from scratch
//! and unit-tested:
//!
//! * [`rng`] — deterministic SplitMix64 / xoshiro256** PRNG with the
//!   distributions the workload generators need (uniform, exponential,
//!   log-normal, Poisson).
//! * [`cli`] — a declarative command-line parser for the `spotcloud` binary.
//! * [`config`] — a `slurm.conf`-style `Key=Value` config-file parser.
//! * [`fmt`] — ASCII table / aligned-series rendering for experiment reports.
//! * [`error`] — an `anyhow`-style opaque error with context chaining.
//! * [`fxhash`] — the rustc Fx hasher for hot-path hash maps.

pub mod cli;
pub mod config;
pub mod error;
pub mod fmt;
pub mod fxhash;
pub mod rng;
