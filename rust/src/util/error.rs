//! Minimal `anyhow`-style dynamic error (std-only; the offline build vendors
//! no ecosystem crates).
//!
//! Provides the small subset the crate uses: an opaque [`Error`] with a
//! context chain, a [`Result`] alias, a [`Context`] extension trait for
//! `Result`/`Option`, and the [`crate::ensure!`] / [`crate::bail!`] /
//! [`crate::err_msg!`] macros.
//!
//! [`Error`] deliberately does **not** implement `std::error::Error`: that is
//! what makes the blanket `From<E: std::error::Error>` impl coherent (the
//! same trick `anyhow` uses), so `?` converts any standard error into it.

use std::fmt;

/// An opaque error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Error {
            msg: msg.into(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn wrap(self, msg: impl Into<String>) -> Self {
        Error {
            msg: msg.into(),
            cause: Some(Box::new(self)),
        }
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow.
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                first = false;
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.cause.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = c.cause.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                cause: err.map(Box::new),
            });
        }
        err.expect("error chain has at least one message")
    }
}

/// Context-attaching extension for `Result` and `Option` (anyhow-style).
pub trait Context<T> {
    /// Attach a context message, converting the error into [`Error`].
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Attach a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Return an [`Error`] built from a format string unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err(
                $crate::util::error::Error::msg(::std::format!($($arg)+)).into(),
            );
        }
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err(
            $crate::util::error::Error::msg(::std::format!($($arg)+)).into(),
        )
    };
}

/// Build an [`Error`] from a format string (expression form).
#[macro_export]
macro_rules! err_msg {
    ($($arg:tt)+) => {
        $crate::util::error::Error::msg(::std::format!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        assert_eq!(e.message(), "missing file");
        let wrapped: Result<()> = Err::<(), _>(io_err()).context("opening config");
        let err = wrapped.unwrap_err();
        assert_eq!(err.message(), "opening config");
        assert_eq!(err.chain().count(), 2);
        assert_eq!(format!("{err:#}"), "opening config: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.message(), "missing value");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().message(), "x too big: 12");
        assert_eq!(check(7).unwrap_err().message(), "unlucky 7");
        let e = err_msg!("code {}", 42);
        assert_eq!(e.message(), "code 42");
    }

    #[test]
    fn debug_prints_chain() {
        let err = Err::<(), _>(io_err())
            .context("layer one")
            .context("layer two")
            .unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("layer two"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }
}
