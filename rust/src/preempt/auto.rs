//! Scheduler-driven automatic QoS preemption (paper Section II.A).
//!
//! This is Slurm's `PreemptType=preempt/qos` behavior: when an interactive
//! (Normal QoS) job cannot be allocated, the scheduling cycle — *inside the
//! allocation path* — scans preemption candidates, issues one requeue/cancel
//! transaction per victim, and then **defers the preemptor**: the job is
//! only re-examined on a later scheduling cycle, after node cleanup. The
//! cycle waits are what produce the paper's 2–3 orders-of-magnitude
//! scheduling-time degradation; single-partition configurations pay an
//! extra mixed-queue scan penalty and retry cycle on top.

use crate::cluster::{AllocRequest, PartitionLayout};
use crate::job::JobId;
use crate::preempt::lifo::{self, Demand, Order};
use crate::preempt::PreemptMode;
use crate::sched::Scheduler;
use crate::sim::SimTime;

impl Scheduler {
    /// Attempt automatic preemption on behalf of blocked job `id`.
    ///
    /// Charges the candidate scan and requeue transactions to the cycle
    /// cursor, issues the preemption, and defers the job for the configured
    /// number of retry cycles. Returns the advanced cursor.
    pub(crate) fn auto_preempt_for(
        &mut self,
        id: JobId,
        req: AllocRequest,
        mode: PreemptMode,
        mut cursor: SimTime,
    ) -> SimTime {
        let costs = self.costs().clone();
        let single = self.config().layout == PartitionLayout::Single;

        // 1. Candidate scan (QoS dependency walk). Single-partition setups
        //    rescan the mixed queue under the partition lock.
        let victims = self.spot_victims();
        cursor += costs.preempt_scan_base;
        cursor += SimTime(costs.preempt_scan_per_job.0 * victims.len() as u64);
        if single {
            cursor += costs.single_partition_scan_penalty;
        }

        let demand = match req {
            AllocRequest::Cores(c) => Demand::Cores(c),
            AllocRequest::WholeNodes(n) => Demand::WholeNodes(n),
        };
        let Some(selected) = lifo::select_victims(&victims, demand, Order::YoungestFirst) else {
            // Even preempting every spot job would not free enough: the job
            // just stays blocked (no preemption storm).
            return cursor;
        };
        if selected.is_empty() {
            return cursor;
        }

        // 2. Requeue transactions, serialized inside the cycle.
        cursor = self.issue_preemption(&selected, mode, cursor, /* by_cron = */ false);

        // 3. Defer the preemptor: Slurm re-attempts allocation for the
        //    preempting job only on a later scheduling cycle (and only after
        //    the victims' nodes clear their epilog).
        let mut retry_cycles = costs.auto_preempt_retry_cycles;
        if single {
            retry_cycles += 1;
        }
        let epilog_done = cursor + costs.node_epilog;
        let cycle_retry = SimTime(self.now().0 + costs.main_cycle_period.0 * retry_cycles as u64);
        let earliest = epilog_done.max(cycle_retry);
        self.defer_until(id, earliest);
        // Guard the freed resources against requeued spot jobs restarting
        // before the preemptor's retry cycle.
        let cores_per_node = self.cluster().cores_per_node();
        let need_cores = match req {
            AllocRequest::Cores(c) => c,
            AllocRequest::WholeNodes(n) => n * cores_per_node,
        };
        self.reserve_for(id, need_cores);
        self.preempt_requested.insert(id);
        if self.config().event_driven {
            // Even event-driven controllers only pick the deferred job up at
            // its retry time.
            self.request_trigger(earliest);
        }
        cursor
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::{topology, PartitionLayout};
    use crate::job::{JobSpec, JobState, JobType, UserId};
    use crate::preempt::{PreemptApproach, PreemptMode};
    use crate::sched::{LogKind, Scheduler, SchedulerConfig};
    use crate::sim::{SchedCosts, SimTime};

    fn sched(layout: PartitionLayout, mode: PreemptMode) -> Scheduler {
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), layout)
            .with_approach(PreemptApproach::AutoScheduler { mode });
        Scheduler::new(topology::tx2500(), cfg)
    }

    /// Fill the cluster with a triple-mode spot job, as the paper does.
    fn fill_with_spot(s: &mut Scheduler) -> crate::job::JobId {
        let spot = s.submit(JobSpec::spot(UserId(99), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        assert_eq!(s.cluster().idle_cores(), 0);
        spot
    }

    #[test]
    fn requeue_mode_preempts_and_dispatches() {
        let mut s = sched(PartitionLayout::Dual, PreemptMode::Requeue);
        let spot = fill_with_spot(&mut s);
        let inter = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        assert!(
            s.run_until_dispatched(&[inter], SimTime::from_secs(600)),
            "interactive job must eventually dispatch via preemption"
        );
        // The spot job was requeued, not cancelled.
        let st = s.job(spot).unwrap().state;
        assert!(
            matches!(st, JobState::Requeued | JobState::Pending),
            "spot state {st:?}"
        );
        assert!(s.log().count(LogKind::Preempted) >= 1);
        assert_eq!(s.job(inter).unwrap().state, JobState::Running);
        s.cluster().check_invariants().unwrap();
    }

    #[test]
    fn cancel_mode_kills_the_spot_job() {
        let mut s = sched(PartitionLayout::Dual, PreemptMode::Cancel);
        let spot = fill_with_spot(&mut s);
        let inter = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[inter], SimTime::from_secs(600)));
        assert_eq!(s.job(spot).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn preemption_is_much_slower_than_baseline() {
        // Baseline triple-mode on an idle cluster.
        let mut b = Scheduler::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        );
        let jb = b.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        assert!(b.run_until_dispatched(&[jb], SimTime::from_secs(60)));
        let base = b.log().measure(&[jb]).unwrap().total_secs;

        // Same job, but the cluster is full of spot work.
        let mut s = sched(PartitionLayout::Dual, PreemptMode::Requeue);
        fill_with_spot(&mut s);
        let ji = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[ji], SimTime::from_secs(600)));
        let with_preempt = s.log().measure(&[ji]).unwrap().total_secs;

        assert!(
            with_preempt > 10.0 * base,
            "auto preemption ({with_preempt}s) must be ≫ baseline ({base}s)"
        );
    }

    #[test]
    fn single_partition_slower_than_dual() {
        let run = |layout| {
            let mut s = sched(layout, PreemptMode::Requeue);
            fill_with_spot(&mut s);
            let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
            assert!(s.run_until_dispatched(&[j], SimTime::from_secs(1200)));
            s.log().measure(&[j]).unwrap().total_secs
        };
        let single = run(PartitionLayout::Single);
        let dual = run(PartitionLayout::Dual);
        assert!(
            single > dual,
            "single partition ({single}s) must be slower than dual ({dual}s)"
        );
    }

    #[test]
    fn requeued_spot_job_runs_again_after_interactive_leaves() {
        let mut s = sched(PartitionLayout::Dual, PreemptMode::Requeue);
        let spot = fill_with_spot(&mut s);
        let inter = s.submit(
            JobSpec::interactive(UserId(1), JobType::TripleMode, 608)
                .with_run_time(SimTime::from_secs(30)),
        );
        assert!(s.run_until_dispatched(&[inter], SimTime::from_secs(600)));
        // Interactive ends after 30s of run time; the requeued spot job
        // should eventually be dispatched again.
        let horizon = s.now() + SimTime::from_secs(3600);
        s.run_until(horizon);
        assert_eq!(
            s.job(spot).unwrap().state,
            JobState::Running,
            "requeued spot job must restart once resources free up"
        );
        assert!(s.job(spot).unwrap().requeue_count >= 1);
    }

    #[test]
    fn insufficient_spot_resources_leave_job_pending() {
        // Spot covers only 5 nodes; interactive wants all 19 — even full
        // preemption cannot help, so no preemption storm should occur.
        let mut s = sched(PartitionLayout::Dual, PreemptMode::Requeue);
        let spot = s.submit(JobSpec::spot(UserId(99), JobType::TripleMode, 160));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        // Occupy the rest with a long interactive job.
        let filler = s.submit(
            JobSpec::interactive(UserId(2), JobType::Array, 448)
                .with_run_time(SimTime::from_secs(100_000)),
        );
        assert!(s.run_until_dispatched(&[filler], SimTime::from_secs(120)));
        let inter = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        s.run_for(SimTime::from_secs(300));
        assert_eq!(s.job(inter).unwrap().state, JobState::Pending);
        assert_eq!(
            s.job(spot).unwrap().state,
            JobState::Running,
            "spot must NOT be preempted when preemption cannot satisfy the job"
        );
    }
}
