//! The Lua job-submit plugin approach — the paper's negative result.
//!
//! "We first used the Lua job submission script feature available with Slurm
//! to detect a job submission and to preempt a spot job if needed. But this
//! attempt did not work because, although it could detect the job
//! submission, it failed to execute any Slurm commands under the Lua job
//! submission script environment."
//!
//! We model the constraint structurally: the plugin receives the job record
//! (detection works) and a [`SchedCommandGate`] that represents what the
//! plugin environment lets it call — which, for scheduler commands, is
//! nothing. The plugin's preemption attempt therefore always returns
//! [`LuaError::SchedulerCallUnavailable`], and the scheduler proceeds as if
//! no preemption had been requested — exactly the paper's observation.

use crate::job::Job;

/// Errors a job-submit plugin can hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LuaError {
    /// Scheduler commands (scontrol/squeue/...) cannot be executed from the
    /// job-submit plugin environment. This is the paper's failure mode.
    SchedulerCallUnavailable,
}

impl std::fmt::Display for LuaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LuaError::SchedulerCallUnavailable => {
                write!(
                    f,
                    "scheduler commands are unavailable in the job_submit plugin environment"
                )
            }
        }
    }
}

impl std::error::Error for LuaError {}

/// The command surface a submit plugin *wishes* it had. Implementations
/// decide what is actually callable.
pub trait SchedCommandGate {
    /// Request a requeue of a running job (as `scontrol requeue` would).
    fn requeue(&mut self, job: crate::job::JobId) -> Result<(), LuaError>;
}

/// The real plugin environment: detection works, commands do not.
pub struct DenyAllGate;

impl SchedCommandGate for DenyAllGate {
    fn requeue(&mut self, _job: crate::job::JobId) -> Result<(), LuaError> {
        Err(LuaError::SchedulerCallUnavailable)
    }
}

/// Outcome of the plugin run for one submission.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubmitOutcome {
    /// The plugin observed the submission (detection always works).
    pub observed_job_cores: u32,
    /// Result of the attempted preemption call.
    pub preempt_attempt: Result<(), LuaError>,
}

/// The job-submit plugin, as the paper attempted it.
pub struct LuaSubmitPlugin;

impl LuaSubmitPlugin {
    /// Invoked by the scheduler at job arrival. Observes the job and tries
    /// to preempt a spot job through the gate.
    pub fn job_submit(&self, job: &Job, gate: &mut dyn SchedCommandGate) -> SubmitOutcome {
        // Detection: the plugin can read the submission just fine.
        let observed_job_cores = job.spec.cores();
        // Action: any scheduler command fails in this environment.
        let preempt_attempt = gate.requeue(job.id);
        SubmitOutcome {
            observed_job_cores,
            preempt_attempt,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobId, JobSpec, JobType, UserId};
    use crate::sim::SimTime;

    #[test]
    fn plugin_detects_but_cannot_act() {
        let job = Job::new(
            JobId(7),
            JobSpec::interactive(UserId(1), JobType::Array, 4096),
            SimTime::ZERO,
        );
        let mut gate = DenyAllGate;
        let out = LuaSubmitPlugin.job_submit(&job, &mut gate);
        assert_eq!(out.observed_job_cores, 4096, "detection works");
        assert_eq!(
            out.preempt_attempt,
            Err(LuaError::SchedulerCallUnavailable),
            "scheduler commands must fail — the paper's negative result"
        );
    }

    #[test]
    fn a_permissive_gate_would_work() {
        // Counterfactual: the approach itself is sound if the environment
        // allowed commands; the limitation is the plugin sandbox.
        struct AllowAll(Vec<JobId>);
        impl SchedCommandGate for AllowAll {
            fn requeue(&mut self, job: JobId) -> Result<(), LuaError> {
                self.0.push(job);
                Ok(())
            }
        }
        let job = Job::new(
            JobId(3),
            JobSpec::interactive(UserId(1), JobType::TripleMode, 64),
            SimTime::ZERO,
        );
        let mut gate = AllowAll(Vec::new());
        let out = LuaSubmitPlugin.job_submit(&job, &mut gate);
        assert!(out.preempt_attempt.is_ok());
        assert_eq!(gate.0, vec![JobId(3)]);
    }
}
