//! The cron-agent preemption approach — the paper's contribution
//! (Section II.B, Fig 2g).
//!
//! A privileged agent wakes at a fixed interval (the paper uses a one-minute
//! crontab) and, fully outside the scheduler's allocation path:
//!
//! 1. checks how many compute nodes are idle;
//! 2. if fewer than the pre-defined reserve (sized to the per-user resource
//!    limit), requeues running spot jobs in **LIFO (youngest-first)** order
//!    until the reserve is restored;
//! 3. updates the spot QoS `MaxTRESPerUser`/total ceiling so newly arriving
//!    spot jobs can never eat into the reserve.
//!
//! Because an arriving interactive job (≤ the per-user limit) always finds
//! the reserve idle, it schedules at **baseline** speed. The documented
//! limitation: a second large job arriving within one agent interval may
//! have to wait for the next pass (tested below).

use crate::job::QosClass;
use crate::preempt::lifo::{self, Demand, Order};
use crate::preempt::PreemptMode;
use crate::sched::Scheduler;

/// Cron agent parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CronAgentConfig {
    /// Whole nodes to keep idle for the next interactive job. The paper
    /// sizes this to the per-user resource limit (64 KNL nodes = 4096
    /// cores).
    pub reserve_nodes: u32,
}

impl Default for CronAgentConfig {
    fn default() -> Self {
        Self { reserve_nodes: 64 }
    }
}

/// One agent pass. Runs in the scheduler's event loop at `CronTick` events
/// but acts through the same public operations a privileged script would
/// use (`squeue`/`sinfo` queries, `scontrol requeue`, `sacctmgr modify qos`).
pub fn cron_pass(sched: &mut Scheduler, mode: PreemptMode, cfg: &CronAgentConfig) {
    let now = sched.now();
    let pass_cost = sched.costs().cron_pass_overhead;
    let cores_per_node = sched.cluster().cores_per_node();
    let total_cores = sched.cluster().total_cores();
    let reserve_cores = cfg.reserve_nodes * cores_per_node;

    // 1-2. Restore the idle reserve by LIFO-requeueing spot jobs. The agent
    // also covers interactive jobs already waiting in the queue ("preempts
    // any running spot jobs if there are not enough idle nodes available
    // for another interactive job submission"): the demand is the larger of
    // the reserve and the pending interactive need.
    let pending_normal_cores: u32 = sched
        .jobs_in_state(crate::job::JobState::Pending)
        .into_iter()
        .filter_map(|id| {
            let j = sched.job(id)?;
            (j.spec.qos == QosClass::Normal).then(|| j.spec.cores())
        })
        .sum();
    let pending_normal_nodes = pending_normal_cores.div_ceil(cores_per_node);
    let want_idle = cfg
        .reserve_nodes
        .max(pending_normal_nodes)
        .min(sched.cluster().node_count());
    let idle = sched.cluster().idle_node_count();
    if idle < want_idle {
        let shortfall = want_idle - idle;
        let victims = sched.spot_victims();
        // Preempt youngest-first until enough *whole nodes* come free. Spot
        // jobs that share nodes with other jobs cannot restore whole idle
        // nodes, so only whole-node holdings count (triple-mode spot jobs,
        // the recommended spot type in the paper, always qualify).
        if let Some(selected) =
            lifo::select_victims(&victims, Demand::WholeNodes(shortfall), Order::YoungestFirst)
        {
            sched.issue_preemption(&selected, mode, now + pass_cost, /* by_cron = */ true);
        } else if !victims.is_empty() {
            // Partial restoration: requeue everything spot if even that
            // cannot fully restore the reserve (interactive load owns the
            // rest; the agent does not touch normal jobs).
            let all: Vec<_> = {
                let mut v = victims.clone();
                v.sort_by_key(|x| (std::cmp::Reverse(x.queue_time), x.job));
                v.into_iter().map(|x| x.job).collect()
            };
            sched.issue_preemption(&all, mode, now + pass_cost, /* by_cron = */ true);
        }
    }

    // 3. Update the spot ceiling: spot may use everything except the
    //    reserve and what interactive jobs currently hold.
    let normal_used = sched.qos().total_usage(QosClass::Normal) + interactive_cores(sched);
    let cap = total_cores
        .saturating_sub(reserve_cores)
        .saturating_sub(normal_used);
    let qos = sched.qos_mut();
    qos.config_mut(QosClass::Spot).max_tres_total = Some(cap);
    qos.config_mut(QosClass::Spot).max_tres_per_user = Some(cap);
}

/// Cores currently held by Normal-QoS jobs (accounted via user accounting;
/// the QoS table only tracks spot usage caps, so we sum allocations).
fn interactive_cores(sched: &Scheduler) -> u32 {
    sched
        .cluster()
        .allocated_jobs()
        .filter_map(|id| {
            let j = sched.job(id)?;
            if j.spec.qos == QosClass::Normal {
                sched.cluster().allocation_of(id).map(|a| a.cores())
            } else {
                None
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::job::{JobSpec, JobState, JobType, UserId};
    use crate::preempt::PreemptApproach;
    use crate::sched::{LogKind, Scheduler, SchedulerConfig};
    use crate::sim::{SchedCosts, SimTime};

    /// TX-2500 with a 5-node reserve (the per-user limit scaled to the dev
    /// cluster: 160 cores).
    fn sched(reserve_nodes: u32) -> Scheduler {
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(reserve_nodes * 32)
            .with_approach(PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig { reserve_nodes },
            });
        Scheduler::new(topology::tx2500(), cfg)
    }

    #[test]
    fn spot_cap_keeps_reserve_free() {
        let mut s = sched(5);
        // Try to fill the whole cluster with spot work: the QoS ceiling
        // must stop it at total - reserve.
        let ids = s.submit_burst(
            (0..19)
                .map(|_| JobSpec::spot(UserId(9), JobType::TripleMode, 32))
                .collect(),
        );
        s.run_for(SimTime::from_secs(300));
        let running = ids
            .iter()
            .filter(|&&id| s.job(id).unwrap().state == JobState::Running)
            .count();
        assert_eq!(running, 14, "spot may fill all but the 5-node reserve");
        assert!(s.cluster().idle_node_count() >= 5);
    }

    #[test]
    fn interactive_schedules_at_baseline_speed_with_spot_load() {
        // Baseline: idle cluster.
        let mut b = Scheduler::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        );
        let jb = b.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160));
        assert!(b.run_until_dispatched(&[jb], SimTime::from_secs(60)));
        let base = b.log().measure(&[jb]).unwrap().total_secs;

        // Cron-agent cluster, spot-loaded to the cap.
        let mut s = sched(5);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 448)); // 14 nodes
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(120)));
        let ji = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160)); // 5 nodes
        assert!(s.run_until_dispatched(&[ji], SimTime::from_secs(60)));
        let with_spot = s.log().measure(&[ji]).unwrap().total_secs;

        assert!(
            with_spot < base * 3.0,
            "cron approach ({with_spot}s) must be comparable to baseline ({base}s)"
        );
    }

    #[test]
    fn agent_restores_reserve_after_interactive_lands() {
        let mut s = sched(5);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 448));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(120)));
        let ji = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160));
        assert!(s.run_until_dispatched(&[ji], SimTime::from_secs(60)));
        // Reserve consumed (0 idle nodes). Within ~2 agent intervals the
        // agent must requeue spot work to restore 5 idle nodes.
        s.run_for(SimTime::from_secs(200));
        assert!(
            s.cluster().idle_node_count() >= 5,
            "agent must restore the reserve, got {} idle nodes",
            s.cluster().idle_node_count()
        );
        assert!(s.log().count(LogKind::CronPreempted) >= 1);
        assert!(s.job(spot).unwrap().requeue_count >= 1);
    }

    #[test]
    fn second_job_within_interval_waits_documented_limitation() {
        let mut s = sched(5);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 448));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(120)));
        // First job takes the whole reserve.
        let j1 = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160));
        assert!(s.run_until_dispatched(&[j1], SimTime::from_secs(60)));
        // Second job arrives right after — before the agent can possibly
        // free spot resources (requeue + epilog alone take >2s).
        let j2 = s.submit(JobSpec::interactive(UserId(2), JobType::TripleMode, 160));
        s.run_for(SimTime::from_secs(1));
        assert_eq!(
            s.job(j2).unwrap().state,
            JobState::Pending,
            "second job within the cron interval must wait (paper's limitation)"
        );
        // After the agent frees spot resources, it dispatches.
        assert!(s.run_until_dispatched(&[j2], SimTime::from_secs(400)));
    }

    #[test]
    fn agent_never_touches_interactive_jobs() {
        // Reserve of 5 nodes but a user limit covering the whole cluster:
        // an interactive job that takes everything must never be preempted
        // by the agent, even though the reserve cannot be restored.
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(608)
            .with_approach(PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig { reserve_nodes: 5 },
            });
        let mut s = Scheduler::new(topology::tx2500(), cfg);
        let ji = s.submit(
            JobSpec::interactive(UserId(1), JobType::Array, 608).with_run_time(SimTime::from_secs(
                100_000,
            )),
        );
        assert!(s.run_until_dispatched(&[ji], SimTime::from_secs(120)));
        // Reserve cannot be restored (no spot jobs to preempt) — the agent
        // must not preempt the interactive job.
        s.run_for(SimTime::from_secs(300));
        assert_eq!(s.job(ji).unwrap().state, JobState::Running);
        assert_eq!(s.log().count(LogKind::CronPreempted), 0);
    }

    #[test]
    fn cap_tracks_interactive_load() {
        let mut s = sched(5);
        let ji = s.submit(
            JobSpec::interactive(UserId(1), JobType::TripleMode, 160)
                .with_run_time(SimTime::from_secs(100_000)),
        );
        assert!(s.run_until_dispatched(&[ji], SimTime::from_secs(60)));
        s.run_for(SimTime::from_secs(120)); // let the agent run
        let cap = s.qos().config(QosClass::Spot).max_tres_total.unwrap();
        // total 608 - reserve 160 - interactive 160 = 288
        assert_eq!(cap, 288);
    }
}
