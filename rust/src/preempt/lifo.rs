//! Preemption victim selection.
//!
//! The paper preempts spot jobs in "last-in, first-out" order — youngest
//! first — "in order to increase the chance that older spot jobs will finish
//! execution" (Slurm's `preempt_youngest_first`). The selection stops as
//! soon as the freed resources cover the demand.

use crate::job::JobId;
use crate::sim::SimTime;

/// A preemption candidate: a running spot job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Victim {
    /// Job id.
    pub job: JobId,
    /// When it (last) entered the queue — LIFO key.
    pub queue_time: SimTime,
    /// Cores its allocation holds.
    pub cores: u32,
    /// Whole nodes its allocation holds exclusively (0 for core-packed
    /// jobs sharing nodes).
    pub whole_nodes: u32,
}

/// What the preemptor needs freed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Demand {
    /// At least this many cores.
    Cores(u32),
    /// At least this many whole nodes.
    WholeNodes(u32),
}

/// Selection order policy. The paper (and Slurm's `preempt_youngest_first`)
/// uses LIFO; FIFO is implemented for the ablation benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Order {
    /// Youngest (largest queue_time) first — the paper's choice.
    YoungestFirst,
    /// Oldest first (ablation).
    OldestFirst,
}

/// Select the minimal prefix of victims (in the given order) whose combined
/// resources cover `demand`. Returns `None` when even preempting everyone
/// would not satisfy the demand (the preemptor simply cannot fit).
pub fn select_victims(candidates: &[Victim], demand: Demand, order: Order) -> Option<Vec<JobId>> {
    let mut sorted: Vec<&Victim> = candidates.iter().collect();
    // Tie-break by job id for determinism.
    match order {
        Order::YoungestFirst => sorted.sort_by_key(|v| (std::cmp::Reverse(v.queue_time), v.job)),
        Order::OldestFirst => sorted.sort_by_key(|v| (v.queue_time, v.job)),
    }
    let mut chosen = Vec::new();
    let (mut freed_cores, mut freed_nodes) = (0u64, 0u64);
    let satisfied = |cores: u64, nodes: u64| match demand {
        Demand::Cores(c) => cores >= c as u64,
        Demand::WholeNodes(n) => nodes >= n as u64,
    };
    if satisfied(0, 0) {
        return Some(Vec::new());
    }
    for v in sorted {
        chosen.push(v.job);
        freed_cores += v.cores as u64;
        freed_nodes += v.whole_nodes as u64;
        if satisfied(freed_cores, freed_nodes) {
            return Some(chosen);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(id: u64, qt: u64, cores: u32, nodes: u32) -> Victim {
        Victim {
            job: JobId(id),
            queue_time: SimTime::from_secs(qt),
            cores,
            whole_nodes: nodes,
        }
    }

    #[test]
    fn youngest_first_minimal_prefix() {
        let cands = [v(1, 10, 100, 0), v(2, 30, 100, 0), v(3, 20, 100, 0)];
        let got = select_victims(&cands, Demand::Cores(150), Order::YoungestFirst).unwrap();
        // Youngest is job 2 (qt=30), then job 3 (qt=20).
        assert_eq!(got, vec![JobId(2), JobId(3)]);
    }

    #[test]
    fn oldest_first_ablation() {
        let cands = [v(1, 10, 100, 0), v(2, 30, 100, 0)];
        let got = select_victims(&cands, Demand::Cores(50), Order::OldestFirst).unwrap();
        assert_eq!(got, vec![JobId(1)]);
    }

    #[test]
    fn whole_node_demand_counts_nodes_not_cores() {
        // Job 1 holds 64 cores but spread (0 whole nodes); job 2 holds 2
        // whole nodes.
        let cands = [v(1, 50, 64, 0), v(2, 40, 128, 2)];
        let got = select_victims(&cands, Demand::WholeNodes(1), Order::YoungestFirst).unwrap();
        // Youngest (job 1) frees no whole node; must continue to job 2.
        assert_eq!(got, vec![JobId(1), JobId(2)]);
    }

    #[test]
    fn insufficient_returns_none() {
        let cands = [v(1, 10, 100, 1)];
        assert_eq!(select_victims(&cands, Demand::Cores(200), Order::YoungestFirst), None);
        assert_eq!(
            select_victims(&cands, Demand::WholeNodes(2), Order::YoungestFirst),
            None
        );
    }

    #[test]
    fn zero_demand_selects_nothing() {
        let cands = [v(1, 10, 100, 1)];
        assert_eq!(
            select_victims(&cands, Demand::Cores(0), Order::YoungestFirst).unwrap(),
            Vec::<JobId>::new()
        );
    }

    #[test]
    fn tie_broken_by_job_id() {
        let cands = [v(9, 10, 10, 0), v(3, 10, 10, 0)];
        let got = select_victims(&cands, Demand::Cores(10), Order::YoungestFirst).unwrap();
        assert_eq!(got, vec![JobId(3)]);
    }
}
