//! Manual preemption: the modified-`sbatch` experiment (paper Section II.B
//! and Fig 2f).
//!
//! "We modified the Slurm batch job submission command, sbatch, to insert a
//! manual requeue operation before actually submitting an interactive job
//! itself." The preemption runs *synchronously on the submit path, outside
//! the scheduler's allocation loop*: the wrapper requeues enough spot jobs
//! (LIFO), then submits. The scheduling time is measured "from the time when
//! the preemption had started".

use crate::job::{JobId, JobSpec};
use crate::preempt::lifo::{self, Demand, Order};
use crate::preempt::PreemptMode;
use crate::sched::Scheduler;
use crate::sim::SimTime;

/// Result of a manual (requeue-then-submit) submission.
#[derive(Debug, Clone)]
pub struct ManualSubmission {
    /// When the wrapper started issuing requeues — the measurement origin
    /// for Fig 2f.
    pub preempt_start: SimTime,
    /// Spot jobs requeued by the wrapper.
    pub victims: Vec<JobId>,
    /// The submitted interactive job(s).
    pub jobs: Vec<JobId>,
}

/// Submit `specs` (one interactive burst) after manually preempting enough
/// spot jobs to cover their aggregate demand. Mirrors the paper's modified
/// `sbatch`: requeue transactions first, then the normal submissions.
pub fn manual_submit(
    sched: &mut Scheduler,
    specs: Vec<JobSpec>,
    mode: PreemptMode,
) -> ManualSubmission {
    let preempt_start = sched.now();
    let cores_per_node = sched.cluster().cores_per_node();

    // Aggregate demand of the burst, net of already-idle resources.
    let whole_nodes: u32 = specs
        .iter()
        .filter(|s| s.job_type == crate::job::JobType::TripleMode)
        .map(|s| s.cores().div_ceil(cores_per_node))
        .sum();
    let cores: u32 = specs
        .iter()
        .filter(|s| s.job_type != crate::job::JobType::TripleMode)
        .map(|s| s.cores())
        .sum();
    let idle_nodes = sched.cluster().idle_node_count();
    let idle_cores = sched.cluster().idle_cores();
    let demand = if whole_nodes > 0 {
        // Mixed bursts are dominated by the node demand in the paper's
        // experiments (each burst is a single job type).
        Demand::WholeNodes(whole_nodes.saturating_sub(idle_nodes))
    } else {
        Demand::Cores(cores.saturating_sub(idle_cores))
    };

    let victims = sched.spot_victims();
    let selected =
        lifo::select_victims(&victims, demand, Order::YoungestFirst).unwrap_or_default();
    // The wrapper issues the requeue commands serially (scontrol requeue),
    // which the scheduler processes as ordinary requeue transactions.
    sched.issue_preemption(&selected, mode, preempt_start, /* by_cron = */ false);

    // Then submit normally. The jobs will dispatch as soon as the victims'
    // nodes clear their epilog — no scheduler-side deferral.
    let jobs = sched.submit_burst(specs);
    ManualSubmission {
        preempt_start,
        victims: selected,
        jobs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::job::{JobState, JobType, UserId};
    use crate::preempt::PreemptApproach;
    use crate::sched::{Scheduler, SchedulerConfig};
    use crate::sim::SchedCosts;

    fn sched() -> Scheduler {
        // Manual preemption needs no scheduler-side preemption config: the
        // wrapper does the work. Approach stays Manual for reporting.
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_approach(PreemptApproach::Manual {
                mode: PreemptMode::Requeue,
            });
        Scheduler::new(topology::tx2500(), cfg)
    }

    #[test]
    fn manual_preempt_then_fast_dispatch() {
        let mut s = sched();
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));

        let sub = manual_submit(
            &mut s,
            vec![JobSpec::interactive(UserId(1), JobType::TripleMode, 608)],
            PreemptMode::Requeue,
        );
        assert_eq!(sub.victims, vec![spot]);
        assert!(s.run_until_dispatched(&sub.jobs, SimTime::from_secs(120)));
        let m = s.log().measure_from(sub.preempt_start, &sub.jobs).unwrap();
        // requeue (0.3s) + epilog (2s) + dispatch (~0.3s): single-digit
        // seconds, ~10x the 0.25s baseline but far from auto-preemption's
        // multi-minute stall.
        assert!(
            (0.5..30.0).contains(&m.total_secs),
            "manual triple-mode took {}s",
            m.total_secs
        );
    }

    #[test]
    fn manual_much_faster_than_auto() {
        // Auto preemption.
        let auto_cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_approach(PreemptApproach::AutoScheduler {
                mode: PreemptMode::Requeue,
            });
        let mut a = Scheduler::new(topology::tx2500(), auto_cfg);
        let spot = a.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
        assert!(a.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        let j = a.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        assert!(a.run_until_dispatched(&[j], SimTime::from_secs(600)));
        let auto_secs = a.log().measure(&[j]).unwrap().total_secs;

        // Manual.
        let mut m = sched();
        let spot = m.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
        assert!(m.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        let sub = manual_submit(
            &mut m,
            vec![JobSpec::interactive(UserId(1), JobType::TripleMode, 608)],
            PreemptMode::Requeue,
        );
        assert!(m.run_until_dispatched(&sub.jobs, SimTime::from_secs(120)));
        let manual_secs = m.log().measure_from(sub.preempt_start, &sub.jobs).unwrap().total_secs;

        assert!(
            manual_secs * 2.0 < auto_secs,
            "manual ({manual_secs}s) must be well under auto ({auto_secs}s)"
        );
    }

    #[test]
    fn idle_resources_reduce_preemption() {
        let mut s = sched();
        // Spot uses only 10 of 19 nodes; a 9-node interactive job needs no
        // preemption at all.
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 320));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        let sub = manual_submit(
            &mut s,
            vec![JobSpec::interactive(UserId(1), JobType::TripleMode, 288)],
            PreemptMode::Requeue,
        );
        assert!(sub.victims.is_empty(), "no preemption needed");
        assert!(s.run_until_dispatched(&sub.jobs, SimTime::from_secs(60)));
        assert_eq!(s.job(spot).unwrap().state, JobState::Running);
    }

    #[test]
    fn lifo_order_spares_older_spot_jobs() {
        let mut s = sched();
        let old_spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 320)); // 10 nodes
        assert!(s.run_until_dispatched(&[old_spot], SimTime::from_secs(60)));
        s.run_for(SimTime::from_secs(60));
        let young_spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 288)); // 9 nodes
        assert!(s.run_until_dispatched(&[young_spot], SimTime::from_secs(60)));

        let sub = manual_submit(
            &mut s,
            vec![JobSpec::interactive(UserId(1), JobType::TripleMode, 160)], // 5 nodes
            PreemptMode::Requeue,
        );
        assert_eq!(sub.victims, vec![young_spot], "youngest-first selection");
        assert_eq!(s.job(old_spot).unwrap().state, JobState::Running);
    }
}
