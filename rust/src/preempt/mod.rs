//! The four spot-job preemption approaches from the paper.
//!
//! | Approach | Where it runs | Paper verdict |
//! |---|---|---|
//! | [`PreemptApproach::AutoScheduler`] | inside the scheduler's allocation path ("Resource Allocation Policies" in Fig 1) | 2–3 orders of magnitude scheduling-time degradation |
//! | [`lua`] submit plugin | queue management hook at submission | **fails** — cannot execute scheduler commands |
//! | [`PreemptApproach::Manual`] (modified `sbatch`) | synchronously before submission | ≈ baseline for individual/array; ~10× for triple-mode |
//! | [`PreemptApproach::CronAgent`] | an independent privileged process | ≈ baseline for everything (the contribution) |
//!
//! The engines themselves are implemented as `impl Scheduler` extensions in
//! [`auto`], [`manual`], and [`cron`]; victim selection is in [`lifo`].

pub mod auto;
pub mod cron;
pub mod lifo;
pub mod lua;
pub mod manual;

pub use cron::CronAgentConfig;

/// Slurm preemption modes (paper Section II.A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PreemptMode {
    /// Preempted job is cancelled and automatically resubmitted. The mode
    /// the paper selects.
    Requeue,
    /// Preempted job is cancelled outright (owner must notice + resubmit).
    Cancel,
    /// Preempted job is frozen in memory on its nodes. Rejected by the
    /// paper: the interactive job does not get the node's full memory.
    Suspend,
    /// Timeshare with the preemptor. Rejected by the paper: resources are
    /// shared between the jobs.
    Gang,
}

impl PreemptMode {
    /// Report label.
    pub fn label(self) -> &'static str {
        match self {
            PreemptMode::Requeue => "REQUEUE",
            PreemptMode::Cancel => "CANCEL",
            PreemptMode::Suspend => "SUSPEND",
            PreemptMode::Gang => "GANG",
        }
    }

    /// Does this mode free the victim's cores for the preemptor?
    /// SUSPEND keeps memory (and in our model the node) occupied; GANG
    /// timeshares. That is exactly why the paper rejects them.
    pub fn frees_resources(self) -> bool {
        matches!(self, PreemptMode::Requeue | PreemptMode::Cancel)
    }
}

impl std::fmt::Display for PreemptMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Which preemption machinery the scheduler is configured with.
#[derive(Debug, Clone, PartialEq)]
pub enum PreemptApproach {
    /// No preemption: interactive jobs wait for resources (baseline).
    None,
    /// Scheduler-driven automatic QoS preemption inside the allocation path.
    AutoScheduler {
        /// What happens to victims.
        mode: PreemptMode,
    },
    /// Modified-`sbatch` manual preemption: the submit wrapper requeues spot
    /// jobs synchronously, then submits (`manual::manual_submit`).
    Manual {
        /// What happens to victims.
        mode: PreemptMode,
    },
    /// The paper's contribution: an independent privileged cron agent
    /// requeues spot jobs LIFO and maintains an idle-node reserve.
    CronAgent {
        /// What happens to victims.
        mode: PreemptMode,
        /// Agent parameters.
        cfg: CronAgentConfig,
    },
}

impl PreemptApproach {
    /// Short label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            PreemptApproach::None => "baseline",
            PreemptApproach::AutoScheduler { .. } => "auto-scheduler",
            PreemptApproach::Manual { .. } => "manual-sbatch",
            PreemptApproach::CronAgent { .. } => "cron-agent",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_resource_semantics() {
        assert!(PreemptMode::Requeue.frees_resources());
        assert!(PreemptMode::Cancel.frees_resources());
        assert!(!PreemptMode::Suspend.frees_resources());
        assert!(!PreemptMode::Gang.frees_resources());
    }

    #[test]
    fn labels() {
        assert_eq!(PreemptMode::Requeue.label(), "REQUEUE");
        assert_eq!(PreemptApproach::None.label(), "baseline");
        assert_eq!(
            PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig::default()
            }
            .label(),
            "cron-agent"
        );
    }
}
