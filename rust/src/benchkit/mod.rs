//! Micro-benchmark harness (criterion substitute, std-only).
//!
//! Used by the `[[bench]] harness = false` targets under `rust/benches/`.
//! Provides warmup, adaptive iteration counts targeting a wall-clock budget,
//! exact percentile reporting via [`crate::metrics::Summary`], and a simple
//! group/report API so each paper figure gets one bench binary printing the
//! same rows the paper plots.

/// Linux-only, like the epoll reactor it measures.
#[cfg(target_os = "linux")]
pub mod connection_scaling;
pub mod coordinator;
pub mod journal_scaling;
pub mod manifest_scaling;
pub mod overload;
pub mod sched_scaling;
pub mod user_scaling;
/// Linux-only, like the sharded reactor front door it measures.
#[cfg(target_os = "linux")]
pub mod shard_scaling;

use crate::metrics::stats::Summary;
use crate::util::fmt::{fmt_seconds, Table};
use std::time::Instant;

/// Configuration for a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup wall-clock budget in seconds.
    pub warmup_secs: f64,
    /// Measurement wall-clock budget in seconds.
    pub measure_secs: f64,
    /// Minimum measured iterations regardless of budget.
    pub min_iters: u32,
    /// Maximum measured iterations (caps very fast benchmarks).
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_secs: 0.2,
            measure_secs: 1.0,
            min_iters: 5,
            max_iters: 1_000,
        }
    }
}

/// Quick config for expensive end-to-end benches (fewer iterations).
impl BenchConfig {
    /// Config tuned for heavier benchmarks (whole-simulation runs).
    pub fn heavy() -> Self {
        Self {
            warmup_secs: 0.0,
            measure_secs: 2.0,
            min_iters: 3,
            max_iters: 30,
        }
    }

    /// Honor `SPOTCLOUD_BENCH_FAST=1` to cut budgets (CI smoke mode).
    pub fn from_env(mut self) -> Self {
        if std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1") {
            self.warmup_secs = 0.0;
            self.measure_secs = self.measure_secs.min(0.2);
            self.min_iters = 2;
            self.max_iters = self.max_iters.min(10);
        }
        self
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Per-iteration wall time summary (seconds).
    pub summary: Summary,
    /// Optional throughput denominator ("items" per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Items per second, when a throughput denominator was attached.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.summary.mean)
    }
}

/// Run one benchmark: calls `f` repeatedly, timing each call.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let warm_start = Instant::now();
    while warm_start.elapsed().as_secs_f64() < cfg.warmup_secs {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let measure_start = Instant::now();
    loop {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        let done_budget = measure_start.elapsed().as_secs_f64() >= cfg.measure_secs;
        if (done_budget && samples.len() as u32 >= cfg.min_iters)
            || samples.len() as u32 >= cfg.max_iters
        {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples).expect("at least one sample"),
        items_per_iter: None,
    }
}

/// A named group of benchmarks that prints a report table on `finish`.
pub struct BenchGroup {
    title: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchGroup {
    /// Create a group with the default config (honoring env overrides).
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            cfg: BenchConfig::default().from_env(),
            results: Vec::new(),
        }
    }

    /// Override the config.
    pub fn config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg.from_env();
        self
    }

    /// Run and record one benchmark.
    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) -> &mut Self {
        let r = bench(name, &self.cfg, f);
        eprintln!(
            "  {:<40} mean {:>12}  p50 {:>12}  n={}",
            r.name,
            fmt_seconds(r.summary.mean),
            fmt_seconds(r.summary.p50),
            r.summary.n
        );
        self.results.push(r);
        self
    }

    /// Run and record one benchmark with a throughput denominator.
    pub fn bench_with_items<T>(&mut self, name: &str, items: f64, f: impl FnMut() -> T) -> &mut Self {
        let mut r = bench(name, &self.cfg, f);
        r.items_per_iter = Some(items);
        eprintln!(
            "  {:<40} mean {:>12}  {:>14.0} items/s  n={}",
            r.name,
            fmt_seconds(r.summary.mean),
            r.throughput().unwrap_or(0.0),
            r.summary.n
        );
        self.results.push(r);
        self
    }

    /// Access results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the final report table and return the results.
    pub fn finish(self) -> Vec<BenchResult> {
        let mut t = Table::new(vec!["benchmark", "mean", "p50", "p90", "min", "iters", "throughput"])
            .with_title(format!("== {} ==", self.title));
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                fmt_seconds(r.summary.mean),
                fmt_seconds(r.summary.p50),
                fmt_seconds(r.summary.p90),
                fmt_seconds(r.summary.min),
                r.summary.n.to_string(),
                r.throughput()
                    .map(|t| format!("{t:.0}/s"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        println!("{}", t.render());
        self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let cfg = BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.05,
            min_iters: 3,
            max_iters: 10,
        };
        let r = bench("sleep", &cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.summary.mean >= 0.002, "mean {}", r.summary.mean);
        assert!(r.summary.n >= 3);
    }

    #[test]
    fn max_iters_caps() {
        let cfg = BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 10.0,
            min_iters: 1,
            max_iters: 7,
        };
        let r = bench("fast", &cfg, || 1 + 1);
        assert_eq!(r.summary.n, 7);
    }

    #[test]
    fn throughput_computed() {
        let cfg = BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.01,
            min_iters: 2,
            max_iters: 5,
        };
        let mut r = bench("t", &cfg, || std::hint::black_box(42));
        r.items_per_iter = Some(100.0);
        assert!(r.throughput().unwrap() > 0.0);
    }
}
