//! Overload scenario — the CI gate for the admission control plane.
//!
//! The best-of-both-worlds claim under pressure: a batch flood must not be
//! able to buy batch throughput with interactive latency. One report
//! (`BENCH_overload.json`) answers three questions:
//!
//! 1. **What does a batch flood cost the interactive path?** The same
//!    submit-then-WAIT interactive loop is timed over real TCP twice — once
//!    against an idle daemon, once while flooder connections hammer batch
//!    submissions from a rate-limited user. CI gates the flooded
//!    interactive WAIT p99 at ≤ 3× the unflooded one.
//! 2. **Does shedding stay where it belongs?** The flood must shed
//!    (typed `overloaded` + retry hint — `shed_batch_requests > 0`) while
//!    the interactive user, inside its own token bucket, is never refused
//!    (`interactive_sheds == 0`).
//! 3. **Does the health surface tell the truth?** While the flood is hot
//!    the daemon must report `shedding` over the `HEALTH` verb, and once
//!    the flood stops it must recover to `healthy` within a probe interval
//!    (both recorded as booleans and gated).
//!
//! Interactive and batch ride different partitions (`Dual` layout), so the
//! gate isolates *control-plane* interference — queue depth, admission
//! locks, reactor backlog — exactly the coupling the overload plane exists
//! to bound.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::{
    Client, ClientError, Daemon, DaemonConfig, ErrorCode, HealthState, OverloadConfig, Server,
    SubmitSpec,
};
use crate::job::{JobType, QosClass};
use crate::metrics::stats::percentile;
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Scenario shape.
#[derive(Debug, Clone)]
pub struct OverloadBenchConfig {
    /// Interactive submit+WAIT round trips timed per phase. Must stay
    /// below `user_burst` so the interactive user never exhausts its own
    /// bucket — the zero-interactive-sheds gate is then a statement about
    /// isolation, not about the interactive user's arrival rate.
    pub interactive_ops: usize,
    /// Flooder connections.
    pub flood_conns: usize,
    /// Jobs per flood submission (`count=`): the flood attempts
    /// `flood_target_jobs` and keeps flooding until the interactive loop
    /// finishes, whichever is longer.
    pub flood_count_per_req: u32,
    /// Minimum jobs the flood must attempt (50k by default).
    pub flood_target_jobs: u64,
    /// Per-user token refill (jobs' worth of requests per second).
    pub user_rate: f64,
    /// Per-user burst capacity.
    pub user_burst: f64,
}

impl Default for OverloadBenchConfig {
    fn default() -> Self {
        Self {
            interactive_ops: 150,
            flood_conns: 2,
            flood_count_per_req: 25,
            flood_target_jobs: 50_000,
            user_rate: 50.0,
            user_burst: 200.0,
        }
    }
}

impl OverloadBenchConfig {
    /// Sub-second smoke shape (`SPOTCLOUD_BENCH_FAST=1`, unit tests).
    pub fn quick() -> Self {
        Self {
            interactive_ops: 25,
            flood_conns: 2,
            flood_count_per_req: 25,
            flood_target_jobs: 2_000,
            user_rate: 50.0,
            user_burst: 200.0,
        }
    }
}

/// What one run measured.
#[derive(Debug, Clone)]
pub struct OverloadReport {
    /// Interactive round trips per phase.
    pub interactive_ops: usize,
    /// Interactive WAIT p99 against the idle daemon (µs).
    pub p99_unflooded_us: f64,
    /// Interactive WAIT p99 under the batch flood (µs).
    pub p99_flooded_us: f64,
    /// p99_flooded / p99_unflooded — the CI gate (≤ 3.0).
    pub flooded_vs_unflooded_ratio: f64,
    /// Jobs the flood attempted (requests × count).
    pub flood_jobs_attempted: u64,
    /// Flood requests admitted (inside the batch user's budget).
    pub flood_requests_admitted: u64,
    /// Flood requests shed with the typed `overloaded` — the CI gate
    /// (> 0: the flood was actually refused, not absorbed).
    pub shed_batch_requests: u64,
    /// Interactive submissions refused — the CI gate (must be 0).
    pub interactive_sheds: u64,
    /// The daemon reported `shedding` over HEALTH while the flood was hot.
    pub observed_shedding: bool,
    /// The daemon recovered to `healthy` after the flood stopped.
    pub recovered_healthy: bool,
}

impl OverloadReport {
    /// The machine-readable record CI uploads (`BENCH_overload.json`).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"overload\",\n",
                "  \"interactive_ops\": {},\n",
                "  \"p99_unflooded_us\": {:.3},\n",
                "  \"p99_flooded_us\": {:.3},\n",
                "  \"flooded_vs_unflooded_ratio\": {:.3},\n",
                "  \"flood_jobs_attempted\": {},\n",
                "  \"flood_requests_admitted\": {},\n",
                "  \"shed_batch_requests\": {},\n",
                "  \"interactive_sheds\": {},\n",
                "  \"observed_shedding\": {},\n",
                "  \"recovered_healthy\": {}\n",
                "}}\n",
            ),
            self.interactive_ops,
            self.p99_unflooded_us,
            self.p99_flooded_us,
            self.flooded_vs_unflooded_ratio,
            self.flood_jobs_attempted,
            self.flood_requests_admitted,
            self.shed_batch_requests,
            self.interactive_sheds,
            self.observed_shedding,
            self.recovered_healthy,
        )
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "overload: {} interactive ops — WAIT p99 unflooded {:.0}us, flooded {:.0}us \
             (ratio {:.2}x, gate 3x); flood attempted {} jobs, admitted {} reqs, \
             shed {} reqs; interactive sheds {} (gate 0); \
             shedding observed={} recovered={}",
            self.interactive_ops,
            self.p99_unflooded_us,
            self.p99_flooded_us,
            self.flooded_vs_unflooded_ratio,
            self.flood_jobs_attempted,
            self.flood_requests_admitted,
            self.shed_batch_requests,
            self.interactive_sheds,
            self.observed_shedding,
            self.recovered_healthy,
        )
    }
}

/// A TCP daemon with the overload plane armed: per-user buckets sized so
/// the interactive loop fits inside its burst while the flood does not.
fn spawn_daemon(cfg: &OverloadBenchConfig) -> (Arc<Daemon>, String, std::thread::JoinHandle<()>) {
    let sched = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(1_000_000);
    let daemon = Daemon::new(
        topology::tx2500(),
        sched,
        DaemonConfig {
            speedup: 5_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(86_400.0),
            overload: OverloadConfig {
                user_rate: cfg.user_rate,
                user_burst: cfg.user_burst,
                ..OverloadConfig::default()
            },
            ..DaemonConfig::default()
        },
    );
    Arc::clone(&daemon).spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = std::thread::spawn(move || server.serve());
    (daemon, addr, handle)
}

/// The interactive loop: submit one 1-task job and WAIT it out, timing the
/// WAIT round trip. Returns the p99 (µs); shed submissions are counted
/// instead of panicking so the gate can report them.
fn interactive_p99_us(addr: &str, ops: usize, sheds: &mut u64) -> f64 {
    let mut c = Client::connect_v2(addr).expect("interactive connect");
    let mut lat_us = Vec::with_capacity(ops);
    for _ in 0..ops {
        let ack = match c.submit(
            &SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 1).with_run_secs(1.0),
        ) {
            Ok(ack) => ack,
            Err(ClientError::Api(e)) if e.code == ErrorCode::Overloaded => {
                *sheds += 1;
                continue;
            }
            Err(e) => panic!("interactive submit failed: {e}"),
        };
        let ids: Vec<u64> = ack.ids().collect();
        let t0 = Instant::now();
        let w = c.wait(&ids, 30.0).expect("interactive WAIT");
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
        assert!(!w.timed_out, "interactive WAIT timed out under load");
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    percentile(&lat_us, 0.99)
}

/// Poll HEALTH until `want` (or the deadline); true when observed.
fn poll_health(c: &mut Client, want: HealthState, deadline: Duration) -> bool {
    let until = Instant::now() + deadline;
    while Instant::now() < until {
        if c.health().map_or(false, |h| h.state == want) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    false
}

/// Run the scenario.
pub fn run_overload(cfg: &OverloadBenchConfig) -> OverloadReport {
    // Phase 1: idle daemon, baseline interactive WAIT p99.
    let mut interactive_sheds = 0u64;
    let p99_unflooded_us = {
        let (daemon, addr, server) = spawn_daemon(cfg);
        let p99 = interactive_p99_us(&addr, cfg.interactive_ops, &mut interactive_sheds);
        daemon.shutdown();
        server.join().expect("server thread");
        p99
    };

    // Phase 2: fresh daemon, the flood hot for the whole measurement.
    let (daemon, addr, server) = spawn_daemon(cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let admitted = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let attempted_reqs = Arc::new(AtomicU64::new(0));
    let per_conn_target = cfg.flood_target_jobs / (cfg.flood_count_per_req as u64)
        / (cfg.flood_conns as u64).max(1)
        + 1;
    let flooders: Vec<_> = (0..cfg.flood_conns.max(1))
        .map(|_| {
            let addr = addr.clone();
            let stop = Arc::clone(&stop);
            let admitted = Arc::clone(&admitted);
            let shed = Arc::clone(&shed);
            let attempted_reqs = Arc::clone(&attempted_reqs);
            let count = cfg.flood_count_per_req;
            std::thread::spawn(move || {
                let mut c = Client::connect_v2(&addr).expect("flood connect");
                let mut sent = 0u64;
                // Run until the target is met AND the interactive loop is
                // done — the pressure must span the whole measurement.
                while sent < per_conn_target || !stop.load(Ordering::Relaxed) {
                    sent += 1;
                    attempted_reqs.fetch_add(1, Ordering::Relaxed);
                    match c.submit(
                        &SubmitSpec::new(QosClass::Spot, JobType::Individual, 1, 9)
                            .with_run_secs(600.0)
                            .with_count(count),
                    ) {
                        Ok(_) => {
                            admitted.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ClientError::Api(e)) if e.code == ErrorCode::Overloaded => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("flood connection failed: {e}"),
                    }
                }
            })
        })
        .collect();

    let p99_flooded_us = interactive_p99_us(&addr, cfg.interactive_ops, &mut interactive_sheds);
    // The flood is still hot: the daemon must be reporting `shedding`.
    let mut probe = Client::connect_v2(&addr).expect("probe connect");
    let observed_shedding = poll_health(&mut probe, HealthState::Shedding, Duration::from_secs(5));
    stop.store(true, Ordering::Relaxed);
    for f in flooders {
        f.join().expect("flooder thread");
    }
    // Flood gone: recovery to `healthy` within a probe interval (the
    // deadline is generous; the probe rides the pacer every ~100ms).
    let recovered_healthy = poll_health(&mut probe, HealthState::Healthy, Duration::from_secs(5));
    daemon.shutdown();
    server.join().expect("server thread");

    let flood_jobs_attempted =
        attempted_reqs.load(Ordering::Relaxed) * cfg.flood_count_per_req as u64;
    OverloadReport {
        interactive_ops: cfg.interactive_ops,
        p99_unflooded_us,
        p99_flooded_us,
        flooded_vs_unflooded_ratio: p99_flooded_us / p99_unflooded_us.max(f64::EPSILON),
        flood_jobs_attempted,
        flood_requests_admitted: admitted.load(Ordering::Relaxed),
        shed_batch_requests: shed.load(Ordering::Relaxed),
        interactive_sheds,
        observed_shedding,
        recovered_healthy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_overload_runs_and_reports() {
        let r = run_overload(&OverloadBenchConfig::quick());
        assert_eq!(r.interactive_sheds, 0, "{r:?}");
        assert!(r.shed_batch_requests > 0, "{r:?}");
        assert!(r.flood_jobs_attempted >= 2_000, "{r:?}");
        assert!(r.p99_unflooded_us > 0.0 && r.p99_unflooded_us.is_finite(), "{r:?}");
        assert!(r.p99_flooded_us > 0.0 && r.p99_flooded_us.is_finite(), "{r:?}");
        assert!(r.observed_shedding, "{r:?}");
        assert!(r.recovered_healthy, "{r:?}");
        let json = r.to_json();
        for key in [
            "\"bench\": \"overload\"",
            "\"p99_unflooded_us\"",
            "\"p99_flooded_us\"",
            "\"flooded_vs_unflooded_ratio\"",
            "\"shed_batch_requests\"",
            "\"interactive_sheds\": 0",
            "\"observed_shedding\": true",
            "\"recovered_healthy\": true",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("overload"));
    }
}
