//! Manifest-admission scaling scenario — the CI gate for the manifest
//! submission path.
//!
//! Three ways to land the same N jobs on a fresh daemon:
//!
//! 1. **manifest** — one `MSUBMIT` carrying an N-entry *heterogeneous*
//!    manifest (interactive + spot, all three launch types, several
//!    users; every entry materializes exactly one job).
//! 2. **homogeneous** — one `SUBMIT count=N` of a single spec (the PR-1
//!    batch path the manifest generalizes).
//! 3. **per-RPC** — N individual `SUBMIT` requests (the client-loop
//!    pattern the paper's launcher had to use).
//!
//! Each path runs against its own daemon with pacing disabled
//! (`speedup = 0`), so the numbers isolate the *admission* cost — parse-
//! free typed requests, per-entry validation, materialization, one
//! scheduler lock, snapshot publish — from dispatch work. CI gates on the
//! manifest's per-job overhead staying within 1.5× of the homogeneous
//! batch: heterogeneity must not reintroduce a per-job penalty.
//!
//! A fourth section races the **codecs** head-to-head on the same
//! manifest's wire bytes: one v2 `MSUBMIT` text line through
//! [`codec::parse_request`] vs one v3 binary frame payload through
//! [`codec::parse_msubmit_v3`]. CI gates v3 parsing at ≥ 2× the v2
//! entry throughput with zero parse errors — the varint record format
//! has to actually buy its keep before a client defaults to it.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::api::{ProtocolVersion, Request, Response, SubmitSpec};
use crate::coordinator::codec;
use crate::coordinator::{Daemon, DaemonConfig};
use crate::job::{JobType, QosClass};
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use crate::workload::manifests;
use std::sync::Arc;
use std::time::Instant;

/// Scenario shape.
#[derive(Debug, Clone)]
pub struct ManifestScalingConfig {
    /// Manifest entries (= jobs per path).
    pub entries: usize,
    /// Distinct interactive users in the mixed manifest.
    pub users: u32,
    /// Timing repetitions per path (fresh daemon each; minimum wins).
    pub iters: usize,
    /// RNG seed for the mixed manifest.
    pub seed: u64,
}

impl Default for ManifestScalingConfig {
    fn default() -> Self {
        Self {
            entries: 10_000,
            users: 5,
            iters: 3,
            seed: 0x5107_c10d,
        }
    }
}

impl ManifestScalingConfig {
    /// Sub-second smoke shape (`SPOTCLOUD_BENCH_FAST=1`, unit tests).
    pub fn quick() -> Self {
        Self {
            entries: 1_000,
            users: 5,
            iters: 1,
            seed: 0x5107_c10d,
        }
    }
}

/// What one run measured.
#[derive(Debug, Clone)]
pub struct ManifestScalingReport {
    /// Entries per manifest (= jobs per path).
    pub entries: usize,
    /// Wall seconds for the one-RPC manifest submission (min over iters).
    pub wall_manifest_s: f64,
    /// Wall seconds for the one-RPC homogeneous `count=N` submission.
    pub wall_homog_s: f64,
    /// Wall seconds for N per-job RPCs.
    pub wall_per_rpc_s: f64,
    /// Manifest admission cost per job (µs).
    pub per_job_manifest_us: f64,
    /// Homogeneous-batch admission cost per job (µs).
    pub per_job_homog_us: f64,
    /// Per-RPC admission cost per job (µs).
    pub per_job_per_rpc_us: f64,
    /// per_job_manifest / per_job_homog — the CI gate (≤ 1.5).
    pub manifest_vs_homog_ratio: f64,
    /// per_job_per_rpc / per_job_manifest (how much one RPC per job costs).
    pub per_rpc_vs_manifest_ratio: f64,
    /// Every manifest entry accepted on every iteration?
    pub all_accepted: bool,
    /// Per-entry id ranges contiguous and in order on every iteration?
    pub ids_contiguous: bool,
    /// v2 text `MSUBMIT` line parse throughput (entries/s, best rep).
    pub v2_parse_entries_per_sec: f64,
    /// v3 binary frame payload parse throughput (entries/s, best rep).
    pub v3_parse_entries_per_sec: f64,
    /// v3 / v2 parse throughput — the CI gate (≥ 2).
    pub v3_vs_v2_parse_ratio: f64,
    /// v3 parses that errored or round-tripped unequal — the CI gate (0).
    pub v3_parse_errors: u64,
}

impl ManifestScalingReport {
    /// The machine-readable record CI uploads (`BENCH_manifest.json`).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"manifest_scaling\",\n",
                "  \"entries\": {},\n",
                "  \"wall_manifest_s\": {:.6},\n",
                "  \"wall_homog_s\": {:.6},\n",
                "  \"wall_per_rpc_s\": {:.6},\n",
                "  \"per_job_manifest_us\": {:.3},\n",
                "  \"per_job_homog_us\": {:.3},\n",
                "  \"per_job_per_rpc_us\": {:.3},\n",
                "  \"manifest_vs_homog_ratio\": {:.3},\n",
                "  \"per_rpc_vs_manifest_ratio\": {:.3},\n",
                "  \"all_accepted\": {},\n",
                "  \"ids_contiguous\": {},\n",
                "  \"v2_parse_entries_per_sec\": {:.0},\n",
                "  \"v3_parse_entries_per_sec\": {:.0},\n",
                "  \"v3_vs_v2_parse_ratio\": {:.3},\n",
                "  \"v3_parse_errors\": {}\n",
                "}}\n",
            ),
            self.entries,
            self.wall_manifest_s,
            self.wall_homog_s,
            self.wall_per_rpc_s,
            self.per_job_manifest_us,
            self.per_job_homog_us,
            self.per_job_per_rpc_us,
            self.manifest_vs_homog_ratio,
            self.per_rpc_vs_manifest_ratio,
            self.all_accepted,
            self.ids_contiguous,
            self.v2_parse_entries_per_sec,
            self.v3_parse_entries_per_sec,
            self.v3_vs_v2_parse_ratio,
            self.v3_parse_errors,
        )
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "manifest_scaling: {} jobs — manifest {:.2}us/job, homogeneous {:.2}us/job \
             (ratio {:.2}x, gate 1.5x), per-RPC {:.2}us/job ({:.1}x manifest)",
            self.entries,
            self.per_job_manifest_us,
            self.per_job_homog_us,
            self.manifest_vs_homog_ratio,
            self.per_job_per_rpc_us,
            self.per_rpc_vs_manifest_ratio,
        )
    }

    /// One-line human summary of the codec head-to-head.
    pub fn parse_summary(&self) -> String {
        format!(
            "codec: v3 binary {:.0} entries/s vs v2 text {:.0} entries/s \
             (ratio {:.2}x, gate 2x; {} parse errors)",
            self.v3_parse_entries_per_sec,
            self.v2_parse_entries_per_sec,
            self.v3_vs_v2_parse_ratio,
            self.v3_parse_errors,
        )
    }
}

/// A fresh admission-only daemon: `speedup = 0` pins virtual time at zero,
/// so no pacing or dispatch work pollutes the submission timing.
fn admission_daemon() -> Arc<Daemon> {
    Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        DaemonConfig {
            speedup: 0.0,
            retire_grace_secs: None,
            history_cap: None,
            ..DaemonConfig::default()
        },
    )
}

/// Run the scenario.
pub fn run_manifest_scaling(cfg: &ManifestScalingConfig) -> ManifestScalingReport {
    let n = cfg.entries;
    let mut all_accepted = true;
    let mut ids_contiguous = true;

    // Path 1: one heterogeneous manifest.
    let mut wall_manifest_s = f64::INFINITY;
    for _ in 0..cfg.iters.max(1) {
        let manifest = manifests::mixed(cfg.seed, n, cfg.users);
        let d = admission_daemon();
        let t0 = Instant::now();
        let resp = d.handle(Request::MSubmit(manifest));
        wall_manifest_s = wall_manifest_s.min(t0.elapsed().as_secs_f64());
        match resp {
            Response::ManifestAck(ack) => {
                all_accepted &= ack.rejected.is_empty() && ack.accepted.len() == n;
                let mut next = ack.accepted.first().map(|a| a.first).unwrap_or(1);
                for acc in &ack.accepted {
                    ids_contiguous &= acc.first == next && acc.last - acc.first + 1 == acc.count;
                    next = acc.last + 1;
                }
            }
            other => panic!("manifest submission failed: {other:?}"),
        }
        d.with_scheduler(|s| s.check_invariants().expect("invariants after manifest"));
    }

    // Path 2: one homogeneous count=N batch.
    let mut wall_homog_s = f64::INFINITY;
    for _ in 0..cfg.iters.max(1) {
        let d = admission_daemon();
        let t0 = Instant::now();
        let resp = d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 1)
                .with_run_secs(600.0)
                .with_count(n as u32),
        ));
        wall_homog_s = wall_homog_s.min(t0.elapsed().as_secs_f64());
        match resp {
            Response::SubmitAck(ack) => assert_eq!(ack.count as usize, n),
            other => panic!("homogeneous submission failed: {other:?}"),
        }
    }

    // Path 3: N per-job RPCs (the client-loop pattern).
    let mut wall_per_rpc_s = f64::INFINITY;
    for _ in 0..cfg.iters.max(1) {
        let d = admission_daemon();
        let t0 = Instant::now();
        for i in 0..n {
            let user = 1 + (i as u32 % cfg.users);
            match d.handle(Request::Submit(
                SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, user)
                    .with_run_secs(600.0),
            )) {
                Response::SubmitAck(_) => {}
                other => panic!("per-RPC submission failed: {other:?}"),
            }
        }
        wall_per_rpc_s = wall_per_rpc_s.min(t0.elapsed().as_secs_f64());
    }

    // Path 4: codec head-to-head — the same manifest's wire bytes parsed
    // as one v2 text MSUBMIT line vs one v3 binary frame payload. No
    // daemon involved: this isolates pure parse cost.
    let manifest = manifests::mixed(cfg.seed, n, cfg.users);
    let v2_line = codec::render_request(&Request::MSubmit(manifest.clone()), ProtocolVersion::V2);
    let v3_payload = codec::render_msubmit_v3(&manifest);
    let mut wall_v2_s = f64::INFINITY;
    let mut wall_v3_s = f64::INFINITY;
    let mut v3_parse_errors = 0u64;
    for _ in 0..cfg.iters.max(1) {
        let t0 = Instant::now();
        let parsed = codec::parse_request(std::hint::black_box(&v2_line), ProtocolVersion::V2);
        wall_v2_s = wall_v2_s.min(t0.elapsed().as_secs_f64());
        match parsed {
            Ok(Request::MSubmit(m)) if m.entries.len() == n => {}
            other => panic!("v2 text re-parse failed: {other:?}"),
        }
        let t0 = Instant::now();
        let parsed = codec::parse_msubmit_v3(std::hint::black_box(&v3_payload));
        wall_v3_s = wall_v3_s.min(t0.elapsed().as_secs_f64());
        match parsed {
            Ok(m) if m == manifest => {}
            _ => v3_parse_errors += 1,
        }
    }

    let per_job = |wall: f64| wall / n as f64 * 1e6;
    let per_job_manifest_us = per_job(wall_manifest_s);
    let per_job_homog_us = per_job(wall_homog_s);
    let per_job_per_rpc_us = per_job(wall_per_rpc_s);
    let v2_parse_entries_per_sec = n as f64 / wall_v2_s.max(f64::EPSILON);
    let v3_parse_entries_per_sec = n as f64 / wall_v3_s.max(f64::EPSILON);
    ManifestScalingReport {
        entries: n,
        wall_manifest_s,
        wall_homog_s,
        wall_per_rpc_s,
        per_job_manifest_us,
        per_job_homog_us,
        per_job_per_rpc_us,
        manifest_vs_homog_ratio: per_job_manifest_us / per_job_homog_us.max(f64::EPSILON),
        per_rpc_vs_manifest_ratio: per_job_per_rpc_us / per_job_manifest_us.max(f64::EPSILON),
        all_accepted,
        ids_contiguous,
        v2_parse_entries_per_sec,
        v3_parse_entries_per_sec,
        v3_vs_v2_parse_ratio: v3_parse_entries_per_sec / v2_parse_entries_per_sec.max(f64::EPSILON),
        v3_parse_errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_manifest_scaling_runs_and_reports() {
        let r = run_manifest_scaling(&ManifestScalingConfig::quick());
        assert!(r.all_accepted, "{r:?}");
        assert!(r.ids_contiguous, "{r:?}");
        assert!(r.wall_manifest_s > 0.0 && r.wall_manifest_s.is_finite());
        let json = r.to_json();
        for key in [
            "\"manifest_vs_homog_ratio\"",
            "\"per_job_manifest_us\"",
            "\"all_accepted\": true",
            "\"ids_contiguous\": true",
            "\"v3_vs_v2_parse_ratio\"",
            "\"v3_parse_errors\": 0",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert_eq!(r.v3_parse_errors, 0, "{r:?}");
        assert!(r.v2_parse_entries_per_sec > 0.0);
        assert!(r.v3_parse_entries_per_sec > 0.0);
        assert!(r.summary().contains("manifest_scaling"));
        assert!(r.parse_summary().contains("v3 binary"));
    }
}
