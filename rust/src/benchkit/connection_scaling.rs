//! Connection-scaling scenario: N established **idle** connections must not
//! tax M **active** clients — the CI bench gate for the epoll reactor.
//!
//! The paper's front door serves thousands of interactive users, most of
//! whom are idle between launches. Under the old threadpool server every
//! idle connection pinned a worker thread and paid a 200 ms poll tick; the
//! reactor keeps them as one epoll registration plus one timer-wheel entry.
//! This scenario proves it at three idle populations (default 100 / 1k /
//! 5k):
//!
//! 1. open N connections, complete one `PING` on each, and leave them idle;
//! 2. watch [`DaemonMetrics::reactor_wakeups`](crate::coordinator::metrics::DaemonMetrics)
//!    over a quiet window — **zero-poll**: the counter must stay flat, as
//!    idle sockets produce no readiness events and their idle deadlines are
//!    far out on the wheel;
//! 3. run M active mixed clients (submit / squeue / stats / util / ping)
//!    and record per-request wall latency plus the server's
//!    accept-to-first-byte histogram.
//!
//! The `connection_scaling` bench binary emits `BENCH_connections.json`
//! and gates: request p99 at the largest idle population within 2× of the
//! smallest, zero request errors, a flat idle wakeup counter, and exactly
//! one reactor thread. Linux-only, like the reactor itself.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::api::SqueueFilter;
use crate::coordinator::{Client, Daemon, DaemonConfig, Server, SubmitSpec};
use crate::job::{JobType, QosClass};
use crate::metrics::LogHistogram;
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shape of one connection-scaling run.
#[derive(Debug, Clone)]
pub struct ConnScalingConfig {
    /// Idle-connection populations, measured independently (fresh daemon
    /// and server per level).
    pub idle_levels: Vec<usize>,
    /// Concurrent active clients per level.
    pub active_clients: usize,
    /// Requests each active client issues.
    pub requests_per_client: usize,
    /// Quiet window over which the reactor wakeup counter must stay flat.
    pub idle_window: Duration,
    /// Request-handling worker pool size.
    pub workers: usize,
    /// Virtual seconds per wall second for the daemon under test.
    pub speedup: f64,
    /// Reactor shards (`SO_REUSEPORT` listeners). 1 preserves the classic
    /// single-reactor run; the `shards` bench sweeps {1, 2, 4}.
    pub shards: usize,
}

impl Default for ConnScalingConfig {
    fn default() -> Self {
        Self {
            idle_levels: vec![100, 1000, 5000],
            active_clients: 4,
            requests_per_client: 300,
            idle_window: Duration::from_millis(500),
            workers: 4,
            speedup: 2_000.0,
            shards: 1,
        }
    }
}

impl ConnScalingConfig {
    /// Sub-second smoke configuration (unit tests, `SPOTCLOUD_BENCH_FAST`).
    pub fn quick() -> Self {
        Self {
            idle_levels: vec![20, 60],
            active_clients: 2,
            requests_per_client: 40,
            idle_window: Duration::from_millis(150),
            workers: 2,
            speedup: 5_000.0,
            shards: 1,
        }
    }
}

/// What one idle-population level measured.
#[derive(Debug, Clone)]
pub struct LevelReport {
    /// Idle connections requested for this level.
    pub idle_target: usize,
    /// Idle connections actually established (short of target only when
    /// the host's fd limit intervened — reported, and the gate notes it).
    pub idle_achieved: usize,
    /// Reactor wakeups during the quiet window (zero-poll: ~0).
    pub reactor_wakeups_while_idle: u64,
    /// Per-request wall latency of the active clients (ns).
    pub request_wall: LogHistogram,
    /// Active-phase wall time (seconds).
    pub active_secs: f64,
    /// Requests completed by the active clients.
    pub requests: u64,
    /// p99 of the server's accept-to-first-byte histogram at this level.
    pub accept_p99_ns: u64,
    /// Reactor threads that served this level's daemon (measured; equals
    /// the configured shard count — exactly 1 in the classic run).
    pub reactor_threads: u64,
    /// Requests that failed (transport or unexpected response) — 0 in a
    /// healthy run.
    pub errors: u64,
}

/// The whole run: one [`LevelReport`] per idle population.
#[derive(Debug, Clone)]
pub struct ConnScalingReport {
    /// Per-level results, in `idle_levels` order.
    pub levels: Vec<LevelReport>,
    /// Most reactor threads any level's daemon ever started — **measured**
    /// via `DaemonMetrics::reactor_threads_started`, so the CI assertion
    /// that `shards` threads multiplex all connections can actually fail.
    pub reactor_threads: u64,
    /// Request-handling pool size used.
    pub workers: usize,
    /// Reactor shards configured.
    pub shards: usize,
}

impl ConnScalingReport {
    /// Active-request p99 at the largest idle population over the smallest
    /// — the scaling gate (≤ 2.0 in CI).
    pub fn p99_ratio(&self) -> f64 {
        let (Some(first), Some(last)) = (self.levels.first(), self.levels.last()) else {
            return f64::NAN;
        };
        last.request_wall.p99().max(1) as f64 / first.request_wall.p99().max(1) as f64
    }

    /// The machine-readable record CI uploads (`BENCH_connections.json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"bench\": \"connection_scaling\",\n");
        out.push_str(&format!("  \"reactor_threads\": {},\n", self.reactor_threads));
        out.push_str(&format!("  \"workers\": {},\n", self.workers));
        out.push_str(&format!("  \"shards\": {},\n", self.shards));
        out.push_str(&format!("  \"p99_ratio\": {:.3},\n", self.p99_ratio()));
        out.push_str("  \"levels\": [\n");
        for (i, l) in self.levels.iter().enumerate() {
            out.push_str(&format!(
                concat!(
                    "    {{\"idle_conns\": {}, \"idle_achieved\": {}, ",
                    "\"reactor_wakeups_while_idle\": {}, ",
                    "\"request_p50_ns\": {}, \"request_p99_ns\": {}, ",
                    "\"reqs_per_sec\": {:.1}, \"accept_p99_ns\": {}, \"errors\": {}}}{}\n",
                ),
                l.idle_target,
                l.idle_achieved,
                l.reactor_wakeups_while_idle,
                l.request_wall.p50(),
                l.request_wall.p99(),
                l.requests as f64 / l.active_secs.max(1e-9),
                l.accept_p99_ns,
                l.errors,
                if i + 1 == self.levels.len() { "" } else { "," },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let per_level: Vec<String> = self
            .levels
            .iter()
            .map(|l| {
                format!(
                    "{}idle: p99={}ns wakeups={} errs={}",
                    l.idle_achieved, l.request_wall.p99(), l.reactor_wakeups_while_idle, l.errors
                )
            })
            .collect();
        format!(
            "connection_scaling: ratio={:.2} [{}] reactor_threads={}",
            self.p99_ratio(),
            per_level.join(" | "),
            self.reactor_threads
        )
    }
}

/// Run the scenario: one fresh daemon + reactor server per idle level.
pub fn run_connection_scaling(cfg: &ConnScalingConfig) -> ConnScalingReport {
    let levels: Vec<LevelReport> = cfg.idle_levels.iter().map(|&n| run_level(n, cfg)).collect();
    let reactor_threads = levels.iter().map(|l| l.reactor_threads).max().unwrap_or(0);
    ConnScalingReport {
        levels,
        reactor_threads,
        workers: cfg.workers,
        shards: cfg.shards.max(1),
    }
}

fn run_level(idle_target: usize, cfg: &ConnScalingConfig) -> LevelReport {
    let daemon = Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        DaemonConfig {
            speedup: cfg.speedup,
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = Server::bind_sharded(Arc::clone(&daemon), "127.0.0.1:0", cfg.workers, cfg.shards)
        .expect("bind")
        // Idle conns must outlive the whole level.
        .with_idle_timeout(Duration::from_secs(600));
    let addr = server.local_addr().expect("addr").to_string();
    let server_thread = std::thread::spawn(move || server.serve());

    // Establish the idle population: one PING each proves the connection
    // is registered and served, then it goes silent.
    let mut idle: Vec<Client> = Vec::with_capacity(idle_target);
    for _ in 0..idle_target {
        match Client::connect(&addr) {
            Ok(mut c) => match c.ping() {
                Ok(()) => idle.push(c),
                Err(e) => {
                    eprintln!("idle ping failed at {}: {e}", idle.len());
                    break;
                }
            },
            Err(e) => {
                // Most likely the fd limit; measure what we got.
                eprintln!("idle connect failed at {} (fd limit?): {e}", idle.len());
                break;
            }
        }
    }
    let idle_achieved = idle.len();

    // Quiet window: the wakeup counter must not move for idle sockets.
    std::thread::sleep(Duration::from_millis(100)); // let completions drain
    let w0 = daemon.metrics.reactor_wakeups.load(Ordering::Relaxed);
    std::thread::sleep(cfg.idle_window);
    let reactor_wakeups_while_idle =
        daemon.metrics.reactor_wakeups.load(Ordering::Relaxed) - w0;

    // Active phase: M clients hammer a launcher-shaped request mix.
    let wall = Arc::new(Mutex::new(LogHistogram::new()));
    let errors = Arc::new(AtomicU64::new(0));
    let requests = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let threads: Vec<_> = (0..cfg.active_clients)
        .map(|t| {
            let addr = addr.clone();
            let wall = Arc::clone(&wall);
            let errors = Arc::clone(&errors);
            let requests = Arc::clone(&requests);
            let reqs = cfg.requests_per_client;
            std::thread::spawn(move || {
                let mut c = match Client::connect_v2(&addr) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("active client {t} failed to connect: {e}");
                        errors.fetch_add(reqs as u64, Ordering::Relaxed);
                        return;
                    }
                };
                let mut local = LogHistogram::new();
                let user = 100 + t as u32;
                for i in 0..reqs {
                    let t1 = Instant::now();
                    let ok = match i % 8 {
                        0 => c
                            .submit(
                                &SubmitSpec::new(QosClass::Spot, JobType::Individual, 1, user)
                                    .with_run_secs(30.0),
                            )
                            .is_ok(),
                        1 => c
                            .squeue(&SqueueFilter {
                                limit: Some(32),
                                ..Default::default()
                            })
                            .is_ok(),
                        2 => c.stats().is_ok(),
                        3 => c.util().is_ok(),
                        _ => c.ping().is_ok(),
                    };
                    local.record(t1.elapsed().as_nanos() as u64);
                    if ok {
                        requests.fetch_add(1, Ordering::Relaxed);
                    } else {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                wall.lock().expect("bench hist").merge(&local);
            })
        })
        .collect();
    for t in threads {
        t.join().expect("active client panicked");
    }
    let active_secs = t0.elapsed().as_secs_f64();
    let accept_p99_ns = daemon.metrics.accept_to_first_byte().p99();

    daemon.shutdown();
    server_thread.join().expect("server thread");
    pacer.join().expect("pacer");
    drop(idle);

    let request_wall = wall.lock().expect("bench hist").clone();
    LevelReport {
        idle_target,
        idle_achieved,
        reactor_wakeups_while_idle,
        request_wall,
        active_secs,
        requests: requests.load(Ordering::Relaxed),
        accept_p99_ns,
        reactor_threads: daemon.metrics.reactor_threads_started.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_connection_scaling_runs_and_reports() {
        let r = run_connection_scaling(&ConnScalingConfig::quick());
        assert_eq!(r.levels.len(), 2);
        assert_eq!(r.reactor_threads, 1);
        for l in &r.levels {
            assert_eq!(l.idle_achieved, l.idle_target, "{l:?}");
            assert_eq!(l.errors, 0, "{l:?}");
            assert!(l.requests > 0, "{l:?}");
            assert!(l.request_wall.count() > 0, "{l:?}");
            // Zero-poll: idle sockets produce no reactor wakeups (tiny
            // slack for a straggling completion event).
            assert!(
                l.reactor_wakeups_while_idle <= 2,
                "idle connections woke the reactor: {l:?}"
            );
        }
        assert!(r.p99_ratio().is_finite());
        let json = r.to_json();
        for key in [
            "\"reactor_threads\"",
            "\"p99_ratio\"",
            "\"request_p99_ns\"",
            "\"reactor_wakeups_while_idle\"",
            "\"accept_p99_ns\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("connection_scaling"));
    }
}
