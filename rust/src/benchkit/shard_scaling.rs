//! Shard-scaling scenario: the tentpole bench for the sharded front door
//! and the partition-sharded scheduler back end.
//!
//! For each shard count in the sweep (default {1, 2, 4}) the scenario
//! boots a fresh daemon with `shard_count` scheduler shards behind
//! [`Server::bind_sharded`]'s `SO_REUSEPORT` reactor shards, then:
//!
//! 1. establishes a large **idle** population (default 50k connections,
//!    fd-limit permitting) and proves **zero-poll per shard**: every
//!    reactor shard's wakeup counter must stay flat over a quiet window —
//!    sharding must not introduce cross-shard chatter for idle sockets;
//! 2. drives a **submit storm**: submitter threads split half `normal`
//!    (interactive partition → sched shard 0) and half `spot` (spot
//!    partition → sched shard 1), so on a sharded daemon the two groups
//!    contend on disjoint scheduler mutexes and disjoint snapshot slots.
//!
//! No pacer runs: the virtual clock stays frozen, so the measured wall
//! time is pure submission-path work (admission, queue insert, EASY
//! shadow, snapshot publish) rather than simulation progress.
//!
//! The `shards` bench binary emits `BENCH_shards.json` and gates:
//! 2-shard submit throughput ≥ 1.6× the 1-shard figure, 2-shard p99 no
//! worse than single-shard (with a small noise allowance), zero request
//! errors, and a flat idle wakeup counter on every shard. Linux-only,
//! like the reactor itself.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::{Client, Daemon, DaemonConfig, Server, SubmitSpec};
use crate::job::{JobType, QosClass};
use crate::metrics::LogHistogram;
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shape of one shard-scaling run.
#[derive(Debug, Clone)]
pub struct ShardScalingConfig {
    /// Shard counts to sweep, ascending; each level gets that many reactor
    /// shards and asks for that many scheduler shards (the scheduler clamps
    /// to the partition count — 2 under the Dual layout).
    pub shard_counts: Vec<usize>,
    /// Idle connections to establish per level before the storm.
    pub idle_conns: usize,
    /// Submitter threads, split evenly between `normal` and `spot` QoS so
    /// a sharded back end sees both scheduler shards loaded.
    pub submitters: usize,
    /// Submissions each submitter issues.
    pub submits_per_thread: usize,
    /// Quiet window over which every shard's wakeup counter must stay flat.
    pub idle_window: Duration,
    /// Request-handling worker pool size.
    pub workers: usize,
}

impl Default for ShardScalingConfig {
    fn default() -> Self {
        Self {
            shard_counts: vec![1, 2, 4],
            idle_conns: 50_000,
            submitters: 8,
            submits_per_thread: 2_000,
            idle_window: Duration::from_millis(500),
            workers: 8,
        }
    }
}

impl ShardScalingConfig {
    /// Sub-second smoke configuration (unit tests, `SPOTCLOUD_BENCH_FAST`).
    pub fn quick() -> Self {
        Self {
            shard_counts: vec![1, 2],
            idle_conns: 48,
            submitters: 4,
            submits_per_thread: 60,
            idle_window: Duration::from_millis(120),
            workers: 4,
        }
    }
}

/// What one shard-count level measured.
#[derive(Debug, Clone)]
pub struct ShardLevelReport {
    /// Shard count this level configured (reactor and requested sched).
    pub shards: usize,
    /// Reactor shards the server actually ran.
    pub reactor_shards: usize,
    /// Scheduler shards the daemon actually ran (clamped to partitions).
    pub sched_shards: usize,
    /// Idle connections requested.
    pub idle_target: usize,
    /// Idle connections actually established (short of target only when
    /// the host's fd limit intervened — reported, and the gate notes it).
    pub idle_achieved: usize,
    /// Worst per-shard wakeup count over the quiet window (zero-poll: ~0
    /// on every shard, so the max is the gate).
    pub idle_wakeups_max_per_shard: u64,
    /// Per-submit wall latency of the storm (ns).
    pub submit_wall: LogHistogram,
    /// Storm wall time (seconds).
    pub storm_secs: f64,
    /// Submissions acknowledged.
    pub submits: u64,
    /// Submissions that failed — 0 in a healthy run.
    pub errors: u64,
}

impl ShardLevelReport {
    /// Acknowledged submissions per wall second.
    pub fn throughput(&self) -> f64 {
        self.submits as f64 / self.storm_secs.max(1e-9)
    }
}

/// The whole sweep: one [`ShardLevelReport`] per shard count.
#[derive(Debug, Clone)]
pub struct ShardScalingReport {
    /// Per-level results, in `shard_counts` order.
    pub levels: Vec<ShardLevelReport>,
}

impl ShardScalingReport {
    fn level(&self, shards: usize) -> Option<&ShardLevelReport> {
        self.levels.iter().find(|l| l.shards == shards)
    }

    /// 2-shard submit throughput over 1-shard — the ≥ 1.6× CI gate. `NaN`
    /// when the sweep lacks either level.
    pub fn throughput_ratio_1_to_2(&self) -> f64 {
        match (self.level(1), self.level(2)) {
            (Some(one), Some(two)) => two.throughput() / one.throughput().max(1e-9),
            _ => f64::NAN,
        }
    }

    /// 2-shard submit p99 over 1-shard — the "p99 no worse" CI gate. `NaN`
    /// when the sweep lacks either level.
    pub fn p99_ratio_1_to_2(&self) -> f64 {
        match (self.level(1), self.level(2)) {
            (Some(one), Some(two)) => {
                two.submit_wall.p99().max(1) as f64 / one.submit_wall.p99().max(1) as f64
            }
            _ => f64::NAN,
        }
    }

    /// The machine-readable record CI uploads (`BENCH_shards.json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"bench\": \"shards\",\n");
        let _ = write!(
            out,
            "  \"throughput_ratio_1_to_2\": {:.3},\n  \"p99_ratio_1_to_2\": {:.3},\n",
            self.throughput_ratio_1_to_2(),
            self.p99_ratio_1_to_2(),
        );
        out.push_str("  \"levels\": [\n");
        for (i, l) in self.levels.iter().enumerate() {
            let _ = write!(
                out,
                concat!(
                    "    {{\"shards\": {}, \"reactor_shards\": {}, \"sched_shards\": {}, ",
                    "\"idle_conns\": {}, \"idle_achieved\": {}, ",
                    "\"idle_wakeups_max_per_shard\": {}, ",
                    "\"submit_p50_ns\": {}, \"submit_p99_ns\": {}, ",
                    "\"submits_per_sec\": {:.1}, \"errors\": {}}}{}\n",
                ),
                l.shards,
                l.reactor_shards,
                l.sched_shards,
                l.idle_target,
                l.idle_achieved,
                l.idle_wakeups_max_per_shard,
                l.submit_wall.p50(),
                l.submit_wall.p99(),
                l.throughput(),
                l.errors,
                if i + 1 == self.levels.len() { "" } else { "," },
            );
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let per_level: Vec<String> = self
            .levels
            .iter()
            .map(|l| {
                format!(
                    "{}sh: {:.0}/s p99={}ns idle_wake={} errs={}",
                    l.shards,
                    l.throughput(),
                    l.submit_wall.p99(),
                    l.idle_wakeups_max_per_shard,
                    l.errors
                )
            })
            .collect();
        format!(
            "shards: x2_throughput={:.2} x2_p99={:.2} [{}]",
            self.throughput_ratio_1_to_2(),
            self.p99_ratio_1_to_2(),
            per_level.join(" | ")
        )
    }
}

/// Run the sweep: one fresh daemon + sharded server per shard count.
pub fn run_shard_scaling(cfg: &ShardScalingConfig) -> ShardScalingReport {
    let levels = cfg.shard_counts.iter().map(|&n| run_level(n, cfg)).collect();
    ShardScalingReport { levels }
}

fn run_level(shards: usize, cfg: &ShardScalingConfig) -> ShardLevelReport {
    let daemon = Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            // The storm parks far more jobs per user than the interactive
            // default admits; per-user fairness is not under test here.
            .with_user_limit(1_000_000),
        DaemonConfig {
            shard_count: shards,
            ..DaemonConfig::default()
        },
    );
    // Deliberately no pacer: a frozen virtual clock keeps the measurement
    // pure submission-path work, with no dispatch churn stealing cycles.
    let sched_shards = daemon.shard_count();
    let server = Server::bind_sharded(Arc::clone(&daemon), "127.0.0.1:0", cfg.workers, shards)
        .expect("bind")
        // Idle conns must outlive the whole level.
        .with_idle_timeout(Duration::from_secs(600));
    let reactor_shards = server.reactor_shards();
    let addr = server.local_addr().expect("addr").to_string();
    let server_thread = std::thread::spawn(move || server.serve());

    // Establish the idle population: one PING each proves the connection
    // is registered and served, then it goes silent. SO_REUSEPORT spreads
    // them across shards kernel-side.
    let mut idle: Vec<Client> = Vec::with_capacity(cfg.idle_conns);
    for _ in 0..cfg.idle_conns {
        match Client::connect(&addr) {
            Ok(mut c) => match c.ping() {
                Ok(()) => idle.push(c),
                Err(e) => {
                    eprintln!("idle ping failed at {}: {e}", idle.len());
                    break;
                }
            },
            Err(e) => {
                // Most likely the fd limit; measure what we got.
                eprintln!("idle connect failed at {} (fd limit?): {e}", idle.len());
                break;
            }
        }
    }
    let idle_achieved = idle.len();

    // Quiet window: no shard's wakeup counter may move for idle sockets.
    std::thread::sleep(Duration::from_millis(100)); // let completions drain
    let shard_metrics = daemon.metrics.reactor_shards();
    let w0: Vec<u64> = shard_metrics
        .iter()
        .map(|s| s.wakeups.load(Ordering::Relaxed))
        .collect();
    std::thread::sleep(cfg.idle_window);
    let idle_wakeups_max_per_shard = shard_metrics
        .iter()
        .zip(&w0)
        .map(|(s, &before)| s.wakeups.load(Ordering::Relaxed) - before)
        .max()
        .unwrap_or(0);

    // Submit storm: even threads hit the interactive partition (normal
    // QoS), odd threads the spot partition, so a sharded scheduler takes
    // the two halves on disjoint mutexes.
    let wall = Arc::new(Mutex::new(LogHistogram::new()));
    let errors = Arc::new(AtomicU64::new(0));
    let submits = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let threads: Vec<_> = (0..cfg.submitters.max(1))
        .map(|t| {
            let addr = addr.clone();
            let wall = Arc::clone(&wall);
            let errors = Arc::clone(&errors);
            let submits = Arc::clone(&submits);
            let reqs = cfg.submits_per_thread;
            std::thread::spawn(move || {
                let mut c = match Client::connect_v2(&addr) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("submitter {t} failed to connect: {e}");
                        errors.fetch_add(reqs as u64, Ordering::Relaxed);
                        return;
                    }
                };
                let qos = if t % 2 == 0 { QosClass::Normal } else { QosClass::Spot };
                // Distinct users per thread keep per-user accounting off
                // the contended path without sharing a counter.
                let user = 1_000 + t as u32;
                let mut local = LogHistogram::new();
                for _ in 0..reqs {
                    let spec =
                        SubmitSpec::new(qos, JobType::Individual, 1, user).with_run_secs(30.0);
                    let t1 = Instant::now();
                    let ok = c.submit(&spec).is_ok();
                    local.record(t1.elapsed().as_nanos() as u64);
                    if ok {
                        submits.fetch_add(1, Ordering::Relaxed);
                    } else {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                wall.lock().expect("bench hist").merge(&local);
            })
        })
        .collect();
    for t in threads {
        t.join().expect("submitter panicked");
    }
    let storm_secs = t0.elapsed().as_secs_f64();

    daemon.shutdown();
    server_thread.join().expect("server thread");
    drop(idle);

    let submit_wall = wall.lock().expect("bench hist").clone();
    let level = ShardLevelReport {
        shards,
        reactor_shards,
        sched_shards,
        idle_target: cfg.idle_conns,
        idle_achieved,
        idle_wakeups_max_per_shard,
        submit_wall,
        storm_secs,
        submits: submits.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
    };
    eprintln!(
        "  {} shard(s) ({} reactor / {} sched): {:.0} submits/s, p99={}ns, \
         idle {}/{} max_wakeups={}",
        level.shards,
        level.reactor_shards,
        level.sched_shards,
        level.throughput(),
        level.submit_wall.p99(),
        level.idle_achieved,
        level.idle_target,
        level.idle_wakeups_max_per_shard,
    );
    level
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_shard_scaling_runs_and_reports() {
        let r = run_shard_scaling(&ShardScalingConfig::quick());
        assert_eq!(r.levels.len(), 2);
        for l in &r.levels {
            assert_eq!(l.idle_achieved, l.idle_target, "{l:?}");
            assert_eq!(l.errors, 0, "{l:?}");
            assert!(l.submits > 0, "{l:?}");
            assert_eq!(l.reactor_shards, l.shards, "{l:?}");
            // Zero-poll holds per shard (tiny slack for a straggling
            // completion event).
            assert!(
                l.idle_wakeups_max_per_shard <= 2,
                "idle connections woke a shard: {l:?}"
            );
        }
        // Dual layout: asking for 2 scheduler shards must yield 2.
        assert_eq!(r.level(2).unwrap().sched_shards, 2);
        assert_eq!(r.level(1).unwrap().sched_shards, 1);
        assert!(r.throughput_ratio_1_to_2().is_finite());
        let json = r.to_json();
        for key in [
            "\"throughput_ratio_1_to_2\"",
            "\"p99_ratio_1_to_2\"",
            "\"idle_wakeups_max_per_shard\"",
            "\"submit_p99_ns\"",
            "\"sched_shards\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("shards:"));
    }
}
