//! User-cardinality scaling scenario — the CI gate for million-user
//! fairshare.
//!
//! Every other bench submits as ~10 distinct users; production launchers
//! fan out over *millions*. This scenario drives Zipf-distributed
//! submissions from 1k → 100k → 1M distinct users through the public
//! `MSUBMIT` admission path (chunked ≤12k-entry manifests from
//! [`crate::workload::manifests::user_scaling_manifests`], every user
//! guaranteed present) against a pacing-disabled daemon, and measures the
//! per-job admission cost at each level. The per-(qos,user) bucket design
//! makes a queue pass O(log u) per visited job, so cost should be nearly
//! flat in user count: CI gates the largest level's per-job cost within
//! 2× of the smallest. The `STATS` user-scale gauges are captured per
//! level, pinning the O(1) snapshot aggregation and making bucket-map
//! growth visible in the uploaded JSON.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::api::{Request, Response};
use crate::coordinator::{Daemon, DaemonConfig};
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use crate::workload::manifests;
use std::sync::Arc;
use std::time::Instant;

/// Scenario shape.
#[derive(Debug, Clone)]
pub struct UserScalingConfig {
    /// Distinct-user levels, ascending (the gate compares last vs first).
    pub levels: Vec<u64>,
    /// Zipf exponent for the hot-extra draw.
    pub exponent: f64,
    /// Timing repetitions per level (fresh daemon each; minimum wins).
    pub iters: usize,
    /// RNG seed for the workload.
    pub seed: u64,
}

impl Default for UserScalingConfig {
    fn default() -> Self {
        Self {
            levels: vec![1_000, 100_000, 1_000_000],
            exponent: 1.1,
            iters: 1,
            seed: 0x05e7_ca1e,
        }
    }
}

impl UserScalingConfig {
    /// Sub-second smoke shape (`SPOTCLOUD_BENCH_FAST=1`, unit tests).
    pub fn quick() -> Self {
        Self {
            levels: vec![200, 2_000],
            exponent: 1.1,
            iters: 1,
            seed: 0x05e7_ca1e,
        }
    }
}

/// What one level measured.
#[derive(Debug, Clone)]
pub struct UserScalingLevel {
    /// Distinct users at this level.
    pub users: u64,
    /// Jobs submitted (one per entry: users + users/4 hot extras).
    pub jobs: u64,
    /// Manifest chunks submitted.
    pub chunks: usize,
    /// Submission wall seconds (min over iters).
    pub wall_s: f64,
    /// Admission cost per job (µs).
    pub per_job_us: f64,
    /// `STATS` gauge after submission: fairshare entries with usage.
    pub users_active: u64,
    /// `STATS` gauge: active + live pending (qos, user) buckets.
    pub users_tracked: u64,
    /// `STATS` gauge: admission token buckets live.
    pub buckets_live: u64,
}

/// What the whole sweep measured.
#[derive(Debug, Clone)]
pub struct UserScalingReport {
    /// Zipf exponent used.
    pub exponent: f64,
    /// Per-level rows, ascending user count.
    pub levels: Vec<UserScalingLevel>,
    /// per_job(largest level) / per_job(smallest level) — the CI gate (≤ 2).
    pub cost_ratio_max_vs_min: f64,
    /// Every entry accepted at every level?
    pub all_accepted: bool,
    /// `users_tracked` ≥ distinct users at every level (gauges are live)?
    pub gauges_cover_users: bool,
}

impl UserScalingReport {
    /// The machine-readable record CI uploads (`BENCH_users.json`).
    pub fn to_json(&self) -> String {
        let mut rows = String::new();
        for (i, l) in self.levels.iter().enumerate() {
            let sep = if i + 1 == self.levels.len() { "" } else { "," };
            rows.push_str(&format!(
                concat!(
                    "    {{ \"users\": {}, \"jobs\": {}, \"chunks\": {}, ",
                    "\"wall_s\": {:.6}, \"per_job_us\": {:.3}, ",
                    "\"users_active\": {}, \"users_tracked\": {}, ",
                    "\"buckets_live\": {} }}{}\n",
                ),
                l.users,
                l.jobs,
                l.chunks,
                l.wall_s,
                l.per_job_us,
                l.users_active,
                l.users_tracked,
                l.buckets_live,
                sep,
            ));
        }
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"user_scaling\",\n",
                "  \"exponent\": {:.2},\n",
                "  \"levels\": [\n{}  ],\n",
                "  \"cost_ratio_max_vs_min\": {:.3},\n",
                "  \"all_accepted\": {},\n",
                "  \"gauges_cover_users\": {}\n",
                "}}\n",
            ),
            self.exponent,
            rows,
            self.cost_ratio_max_vs_min,
            self.all_accepted,
            self.gauges_cover_users,
        )
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let per_level: Vec<String> = self
            .levels
            .iter()
            .map(|l| format!("{}u {:.2}us/job", l.users, l.per_job_us))
            .collect();
        format!(
            "user_scaling: {} (ratio {:.2}x, gate 2x)",
            per_level.join(", "),
            self.cost_ratio_max_vs_min,
        )
    }
}

/// A fresh admission-only daemon (same shape as `manifest_scaling`):
/// `speedup = 0` pins virtual time, isolating submission cost.
fn admission_daemon() -> Arc<Daemon> {
    Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        DaemonConfig {
            speedup: 0.0,
            retire_grace_secs: None,
            history_cap: None,
            ..DaemonConfig::default()
        },
    )
}

/// Run the scenario.
pub fn run_user_scaling(cfg: &UserScalingConfig) -> UserScalingReport {
    assert!(!cfg.levels.is_empty());
    let mut levels = Vec::with_capacity(cfg.levels.len());
    let mut all_accepted = true;
    let mut gauges_cover_users = true;

    for &users in &cfg.levels {
        let manifests = manifests::user_scaling_manifests(cfg.seed, users, cfg.exponent);
        let jobs: u64 = manifests.iter().map(|m| m.jobs()).sum();
        let chunks = manifests.len();

        let mut wall_s = f64::INFINITY;
        let mut gauges = None;
        for _ in 0..cfg.iters.max(1) {
            let batch = manifests.clone();
            let d = admission_daemon();
            let t0 = Instant::now();
            for m in batch {
                let want = m.entries.len();
                match d.handle(Request::MSubmit(m)) {
                    Response::ManifestAck(ack) => {
                        all_accepted &= ack.rejected.is_empty() && ack.accepted.len() == want;
                    }
                    other => panic!("user-scaling submission failed: {other:?}"),
                }
            }
            wall_s = wall_s.min(t0.elapsed().as_secs_f64());
            match d.handle(Request::Stats) {
                Response::Stats(snap) => {
                    let u = snap.users.expect("stats snapshot carries user gauges");
                    gauges_cover_users &= u.users_tracked >= users;
                    gauges = Some(u);
                }
                other => panic!("STATS failed: {other:?}"),
            }
            d.with_scheduler(|s| s.check_invariants().expect("invariants after submission"));
        }

        let g = gauges.expect("at least one iteration");
        levels.push(UserScalingLevel {
            users,
            jobs,
            chunks,
            wall_s,
            per_job_us: wall_s / jobs.max(1) as f64 * 1e6,
            users_active: g.users_active,
            users_tracked: g.users_tracked,
            buckets_live: g.buckets_live,
        });
    }

    let per_job_first = levels.first().map(|l| l.per_job_us).unwrap_or(0.0);
    let per_job_last = levels.last().map(|l| l.per_job_us).unwrap_or(0.0);
    UserScalingReport {
        exponent: cfg.exponent,
        levels,
        cost_ratio_max_vs_min: per_job_last / per_job_first.max(f64::EPSILON),
        all_accepted,
        gauges_cover_users,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_user_scaling_runs_and_reports() {
        let r = run_user_scaling(&UserScalingConfig::quick());
        assert!(r.all_accepted, "{r:?}");
        assert!(r.gauges_cover_users, "{r:?}");
        assert_eq!(r.levels.len(), 2);
        for l in &r.levels {
            assert_eq!(l.jobs, l.users + l.users / 4, "one job per entry");
            assert!(l.wall_s > 0.0 && l.wall_s.is_finite());
            assert!(l.users_tracked >= l.users, "{l:?}");
        }
        let json = r.to_json();
        for key in [
            "\"bench\": \"user_scaling\"",
            "\"cost_ratio_max_vs_min\"",
            "\"users_tracked\"",
            "\"all_accepted\": true",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("user_scaling"));
    }
}
