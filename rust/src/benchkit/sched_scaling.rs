//! Burst-scaling scenario: wall-clock cost of scheduling large bursts of
//! *individual* jobs — the workload the paper fills clusters with, and the
//! one the scheduler's old queue layer went quadratic on.
//!
//! For each burst size N the scenario submits N one-core individual jobs
//! (client-loop style, one submit RPC apart), runs the simulation until
//! every job has dispatched, and reports the wall time per job. With the
//! incremental queue layer the per-job cost must stay near-flat as N grows
//! three orders of magnitude; the `sched_scaling` bench binary gates CI on
//! `per_job_ratio` (largest vs smallest size) staying ≤ 2×.
//!
//! A second scenario drives a mixed spot + interactive workload through
//! scheduler-automatic preemption (requeue churn, reservations, deferral) to
//! prove the data-structure layer holds up under the messy path too — it is
//! reported, invariant-checked, but not part of the flatness gate (preempt
//! deferral is intentionally O(cycles), per the paper).
//!
//! Snapshot capture cost rides along: for the largest burst the scenario
//! also measures a cold (full-table) capture and a delta capture after one
//! job mutation, demonstrating the bounded publish path.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::snapshot::SchedSnapshot;
use crate::job::{JobSpec, JobType, UserId};
use crate::preempt::{PreemptApproach, PreemptMode};
use crate::sched::{Scheduler, SchedulerConfig};
use crate::sim::{SchedCosts, SimTime};
use std::fmt::Write as _;
use std::time::Instant;

/// Shape of the scaling run.
#[derive(Debug, Clone)]
pub struct ScalingConfig {
    /// Individual-burst sizes, ascending (the gate compares last vs first).
    pub sizes: Vec<usize>,
    /// Per-job virtual run time in seconds (short: jobs must cycle through
    /// the 608-core cluster so the queue drains).
    pub run_secs: u64,
    /// Interactive-job count for the mixed preemption scenario.
    pub mixed_jobs: usize,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        Self {
            sizes: vec![1_000, 10_000, 100_000],
            run_secs: 2,
            mixed_jobs: 2_000,
        }
    }
}

impl ScalingConfig {
    /// Sub-second smoke configuration (unit tests, `SPOTCLOUD_BENCH_FAST`).
    pub fn quick() -> Self {
        Self {
            sizes: vec![500, 2_000],
            run_secs: 2,
            mixed_jobs: 300,
        }
    }
}

/// One burst size's measurement.
#[derive(Debug, Clone)]
pub struct SizeResult {
    /// Jobs in the burst.
    pub jobs: usize,
    /// Wall seconds from first submit to last dispatch.
    pub wall_secs: f64,
    /// Wall microseconds of scheduling cost per job.
    pub per_job_us: f64,
    /// Virtual seconds the simulation covered.
    pub virtual_secs: f64,
    /// Dispatches performed (equals `jobs` on a healthy run).
    pub dispatches: u64,
    /// Every job dispatched within the horizon. Recorded, not asserted,
    /// so a regressed run still writes its JSON; the bench binary gates
    /// on it after the write.
    pub completed: bool,
}

/// The mixed preemption scenario's measurement.
#[derive(Debug, Clone)]
pub struct MixedResult {
    /// Interactive jobs pushed through the preemption path.
    pub jobs: usize,
    /// Wall seconds to dispatch them all.
    pub wall_secs: f64,
    /// Preemption victims over the run.
    pub preemptions: u64,
    /// Requeue transactions over the run.
    pub requeues: u64,
    /// Every interactive job dispatched within the horizon (recorded, not
    /// asserted — see [`SizeResult::completed`]).
    pub completed: bool,
}

/// What one scaling run measured.
#[derive(Debug, Clone)]
pub struct ScalingReport {
    /// Per-size results, ascending size.
    pub sizes: Vec<SizeResult>,
    /// Largest-size per-job cost over smallest-size per-job cost — the CI
    /// flatness gate.
    pub per_job_ratio: f64,
    /// Mixed spot/interactive preemption scenario.
    pub mixed: MixedResult,
    /// Cold full-table snapshot capture of the largest burst (µs).
    pub capture_full_us: f64,
    /// Delta capture after one job mutation, against the cold one (µs).
    pub capture_delta_us: f64,
}

impl ScalingReport {
    /// The machine-readable record CI uploads (`BENCH_sched_scaling.json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"bench\": \"sched_scaling\",\n  \"sizes\": [");
        for (i, s) in self.sizes.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let _ = write!(
                out,
                "{sep}\n    {{\"jobs\": {}, \"wall_secs\": {:.4}, \"per_job_us\": {:.3}, \
                 \"virtual_secs\": {:.1}, \"dispatches\": {}, \"completed\": {}}}",
                s.jobs, s.wall_secs, s.per_job_us, s.virtual_secs, s.dispatches, s.completed,
            );
        }
        let _ = write!(
            out,
            "\n  ],\n  \"per_job_ratio\": {:.3},\n  \"mixed\": {{\"jobs\": {}, \
             \"wall_secs\": {:.4}, \"preemptions\": {}, \"requeues\": {}, \
             \"completed\": {}}},\n  \
             \"capture_full_us\": {:.1},\n  \"capture_delta_us\": {:.1}\n}}",
            self.per_job_ratio,
            self.mixed.jobs,
            self.mixed.wall_secs,
            self.mixed.preemptions,
            self.mixed.requeues,
            self.mixed.completed,
            self.capture_full_us,
            self.capture_delta_us,
        );
        out.push('\n');
        out
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let per_size: Vec<String> = self
            .sizes
            .iter()
            .map(|s| format!("{}j={:.2}us/job", s.jobs, s.per_job_us))
            .collect();
        format!(
            "sched_scaling: {} ratio={:.2} | mixed {}j {:.2}s ({} preemptions) | \
             capture full={:.0}us delta={:.0}us",
            per_size.join(" "),
            self.per_job_ratio,
            self.mixed.jobs,
            self.mixed.wall_secs,
            self.mixed.preemptions,
            self.capture_full_us,
            self.capture_delta_us,
        )
    }
}

fn burst_sched() -> Scheduler {
    Scheduler::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
    )
}

/// Run one individual burst of `n` jobs; returns (result, drained scheduler).
fn run_burst(n: usize, run_secs: u64) -> (SizeResult, Scheduler) {
    let mut s = burst_sched();
    let specs: Vec<JobSpec> = (0..n)
        .map(|i| {
            // Eight submitting users: exercises the per-user fairshare
            // buckets without tripping per-user core limits.
            JobSpec::interactive(UserId(1 + (i % 8) as u32), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(run_secs))
        })
        .collect();
    // Generous horizon: drain is controller-serialized at ~12ms of virtual
    // time per dispatch plus cycle overheads.
    let horizon = SimTime::from_secs(n as u64 / 10 + 7_200);
    let t0 = Instant::now();
    let ids = s.submit_burst(specs);
    let completed = s.run_until_dispatched(&ids, horizon);
    let wall_secs = t0.elapsed().as_secs_f64();
    s.check_invariants().expect("invariants after burst");
    (
        SizeResult {
            jobs: n,
            wall_secs,
            per_job_us: wall_secs * 1e6 / n as f64,
            virtual_secs: s.now().as_secs_f64(),
            dispatches: s.stats().dispatches,
            completed,
        },
        s,
    )
}

/// Mixed spot + interactive with scheduler-automatic preemption: spot fills
/// the cluster, then an interactive individual burst must preempt its way
/// in job by job.
fn run_mixed(jobs: usize) -> MixedResult {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(1_000_000)
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Requeue,
        });
    let mut s = Scheduler::new(topology::tx2500(), cfg);
    // 608 one-core spot jobs: every interactive arrival finds a full
    // cluster and preempts exactly what it needs.
    let spot: Vec<JobSpec> = (0..608)
        .map(|i| {
            JobSpec::spot(UserId(100 + (i % 4) as u32), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(30 * 24 * 3600))
        })
        .collect();
    let spot_ids = s.submit_burst(spot);
    assert!(s.run_until_dispatched(&spot_ids, SimTime::from_secs(3_600)));
    let inter: Vec<JobSpec> = (0..jobs)
        .map(|i| {
            JobSpec::interactive(UserId(1 + (i % 8) as u32), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(5))
        })
        .collect();
    let horizon = SimTime::from_secs(jobs as u64 * 40 + 7_200);
    let t0 = Instant::now();
    let ids = s.submit_burst(inter);
    let completed = s.run_until_dispatched(&ids, horizon);
    let wall_secs = t0.elapsed().as_secs_f64();
    s.check_invariants().expect("invariants after mixed preemption");
    MixedResult {
        jobs,
        wall_secs,
        preemptions: s.stats().preemptions,
        requeues: s.stats().requeues,
        completed,
    }
}

/// Run the full scaling scenario.
pub fn run_sched_scaling(cfg: &ScalingConfig) -> ScalingReport {
    assert!(!cfg.sizes.is_empty());
    // Warm the allocator with a tiny untimed burst so the smallest timed
    // size is not dominated by first-touch costs.
    let _ = run_burst(64, cfg.run_secs);
    let mut sizes = Vec::new();
    let mut last_sched = None;
    for &n in &cfg.sizes {
        let (r, s) = run_burst(n, cfg.run_secs);
        eprintln!(
            "  burst {:>7} jobs: {:>8.3}s wall, {:>7.2}us/job, {:.0}s virtual",
            r.jobs, r.wall_secs, r.per_job_us, r.virtual_secs
        );
        sizes.push(r);
        last_sched = Some(s);
    }
    let per_job_ratio = sizes.last().unwrap().per_job_us / sizes.first().unwrap().per_job_us;

    // Snapshot capture cost on the largest table: cold vs delta.
    let mut s = last_sched.expect("at least one size ran");
    let t0 = Instant::now();
    let cold = SchedSnapshot::capture(&s, None);
    let capture_full_us = t0.elapsed().as_secs_f64() * 1e6;
    // One mutation: a fresh submission. The delta capture rebuilds one view
    // and shares every other allocation.
    s.submit(JobSpec::interactive(UserId(1), JobType::Individual, 1));
    let t1 = Instant::now();
    let delta = SchedSnapshot::capture(&s, Some(&cold));
    let capture_delta_us = t1.elapsed().as_secs_f64() * 1e6;
    assert_eq!(delta.jobs().len(), cold.jobs().len() + 1);

    let mixed = run_mixed(cfg.mixed_jobs);
    eprintln!(
        "  mixed {:>7} jobs: {:>8.3}s wall, {} preemptions, {} requeues",
        mixed.jobs, mixed.wall_secs, mixed.preemptions, mixed.requeues
    );
    ScalingReport {
        sizes,
        per_job_ratio,
        mixed,
        capture_full_us,
        capture_delta_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scaling_runs_and_reports() {
        let r = run_sched_scaling(&ScalingConfig::quick());
        assert_eq!(r.sizes.len(), 2);
        assert!(r.sizes.iter().all(|s| s.completed), "{:?}", r.sizes);
        assert!(r.per_job_ratio > 0.0);
        assert!(r.mixed.completed, "{:?}", r.mixed);
        assert!(r.mixed.preemptions > 0);
        let json = r.to_json();
        for key in [
            "\"per_job_ratio\"",
            "\"capture_delta_us\"",
            "\"preemptions\"",
            "\"per_job_us\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("sched_scaling"));
    }
}
