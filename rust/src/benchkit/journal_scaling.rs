//! Journal-scaling scenario — the CI gate for the durability subsystem.
//!
//! Two questions, one report (`BENCH_journal.json`):
//!
//! 1. **What does write-ahead journaling cost at admission?** The same
//!    per-RPC submission loop runs against four otherwise-identical
//!    admission-only daemons — journal *off*, `fsync=never`,
//!    `fsync=interval` (the default), `fsync=always` — and the per-request
//!    p99 is compared. CI gates on the default policy staying within 1.5×
//!    of journal-off: the WAL sits on the ack path of *every* admission,
//!    so its steady-state cost must stay in the noise (one buffered
//!    `write(2)` per record; the fsync stride amortizes the sync).
//! 2. **What does group commit buy back?** `fsync=always` is the honest
//!    policy but the expensive one; with several writers in flight the
//!    daemon batches their acks into shared fsyncs. A 4-thread concurrent
//!    admission loop runs once with the journal off and once under
//!    `fsync=always` + group commit; CI gates the ratio at ≤ 3× — the
//!    whole point of the parked-writer protocol is that full durability
//!    under concurrency costs a small multiple, not an fsync per ack.
//! 3. **How fast is recovery by replay?** A journal is grown to N admit
//!    records with checkpointing pushed out of the way, the daemon is
//!    dropped, and `Daemon::recover` is timed cold — once at the small
//!    shape (1k records), once at the large one (100k by default), and
//!    once sharded (2 scheduler shards, admissions alternating qos so both
//!    per-shard journals grow; replay must reproduce the writer's job ids
//!    exactly), so the replay rate and its scaling are both on record.
//!
//! Every daemon here is frozen (`speedup = 0`): admitted jobs never
//! dispatch, so the timings isolate admission + journaling from pacer
//! work, exactly like `benchkit::manifest_scaling`.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::api::{Request, Response, SubmitSpec};
use crate::coordinator::{Daemon, DaemonConfig, DurabilityConfig, FsyncPolicy};
use crate::job::{JobType, QosClass};
use crate::metrics::stats::percentile;
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use crate::testkit::crash::TempDir;
use std::sync::Arc;
use std::time::Instant;

/// Scenario shape.
#[derive(Debug, Clone)]
pub struct JournalScalingConfig {
    /// Per-RPC admissions timed per fsync policy.
    pub jobs: usize,
    /// Timing repetitions per policy (fresh daemon + journal each; the
    /// best p99 wins, like the min-wall convention elsewhere in benchkit).
    pub iters: usize,
    /// Records in the small recovery journal.
    pub recovery_small: usize,
    /// Records in the large recovery journal.
    pub recovery_large: usize,
    /// Concurrent writer threads for the group-commit rows.
    pub gc_threads: usize,
}

impl Default for JournalScalingConfig {
    fn default() -> Self {
        Self {
            jobs: 2_000,
            iters: 2,
            recovery_small: 1_000,
            recovery_large: 100_000,
            gc_threads: 4,
        }
    }
}

impl JournalScalingConfig {
    /// Sub-second smoke shape (`SPOTCLOUD_BENCH_FAST=1`, unit tests).
    pub fn quick() -> Self {
        Self {
            jobs: 300,
            iters: 1,
            recovery_small: 200,
            recovery_large: 1_000,
            gc_threads: 4,
        }
    }
}

/// What one run measured.
#[derive(Debug, Clone)]
pub struct JournalScalingReport {
    /// Admissions timed per policy.
    pub jobs: usize,
    /// Per-request admission p99 with no journal configured (µs).
    pub p99_off_us: f64,
    /// Per-request admission p99 under `fsync=never` (µs).
    pub p99_never_us: f64,
    /// Per-request admission p99 under `fsync=interval` (default stride, µs).
    pub p99_interval_us: f64,
    /// Per-request admission p99 under `fsync=always` (µs).
    pub p99_always_us: f64,
    /// p99_interval / p99_off — the CI gate (≤ 1.5).
    pub interval_vs_off_ratio: f64,
    /// Concurrent (4-thread) admission p99 with no journal configured (µs).
    pub p99_off_concurrent_us: f64,
    /// Concurrent admission p99 under `fsync=always` + group commit (µs).
    pub p99_always_gc_us: f64,
    /// p99_always_gc / p99_off_concurrent — the CI gate (≤ 3.0).
    pub gc_vs_off_ratio: f64,
    /// Leader fsyncs the group-commit run performed (fewer than acks ⇒
    /// batching happened).
    pub group_commit_batches: u64,
    /// Records in the small recovery journal.
    pub recovery_small_records: usize,
    /// Cold `Daemon::recover` wall seconds at the small shape.
    pub recovery_small_wall_s: f64,
    /// Records in the large recovery journal.
    pub recovery_large_records: usize,
    /// Cold `Daemon::recover` wall seconds at the large shape.
    pub recovery_large_wall_s: f64,
    /// Replay rate at the large shape (records / second).
    pub recovery_large_records_per_s: f64,
    /// Records in the sharded (2-shard) recovery journal.
    pub recovery_sharded_records: usize,
    /// Cold sharded `Daemon::recover` wall seconds.
    pub recovery_sharded_wall_s: f64,
    /// Sharded replay reproduced the writer's job ids exactly (count +
    /// sampled id identity across both shards)?
    pub recovery_sharded_ids_match: bool,
    /// Every submission acked on every iteration?
    pub all_acked: bool,
    /// Both recoveries replayed exactly the records that were journaled?
    pub replay_counts_match: bool,
}

impl JournalScalingReport {
    /// The machine-readable record CI uploads (`BENCH_journal.json`).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"journal_scaling\",\n",
                "  \"jobs\": {},\n",
                "  \"p99_off_us\": {:.3},\n",
                "  \"p99_never_us\": {:.3},\n",
                "  \"p99_interval_us\": {:.3},\n",
                "  \"p99_always_us\": {:.3},\n",
                "  \"interval_vs_off_ratio\": {:.3},\n",
                "  \"p99_off_concurrent_us\": {:.3},\n",
                "  \"p99_always_gc_us\": {:.3},\n",
                "  \"gc_vs_off_ratio\": {:.3},\n",
                "  \"group_commit_batches\": {},\n",
                "  \"recovery_small_records\": {},\n",
                "  \"recovery_small_wall_s\": {:.6},\n",
                "  \"recovery_large_records\": {},\n",
                "  \"recovery_large_wall_s\": {:.6},\n",
                "  \"recovery_large_records_per_s\": {:.1},\n",
                "  \"recovery_sharded_records\": {},\n",
                "  \"recovery_sharded_wall_s\": {:.6},\n",
                "  \"recovery_sharded_ids_match\": {},\n",
                "  \"all_acked\": {},\n",
                "  \"replay_counts_match\": {}\n",
                "}}\n",
            ),
            self.jobs,
            self.p99_off_us,
            self.p99_never_us,
            self.p99_interval_us,
            self.p99_always_us,
            self.interval_vs_off_ratio,
            self.p99_off_concurrent_us,
            self.p99_always_gc_us,
            self.gc_vs_off_ratio,
            self.group_commit_batches,
            self.recovery_small_records,
            self.recovery_small_wall_s,
            self.recovery_large_records,
            self.recovery_large_wall_s,
            self.recovery_large_records_per_s,
            self.recovery_sharded_records,
            self.recovery_sharded_wall_s,
            self.recovery_sharded_ids_match,
            self.all_acked,
            self.replay_counts_match,
        )
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "journal_scaling: {} admissions — p99 off {:.2}us, never {:.2}us, \
             interval {:.2}us (ratio {:.2}x, gate 1.5x), always {:.2}us; \
             group commit always {:.2}us vs off {:.2}us (ratio {:.2}x, gate 3x, {} batches); \
             recovery {} rec {:.3}s / {} rec {:.3}s ({:.0} rec/s) / sharded {} rec {:.3}s",
            self.jobs,
            self.p99_off_us,
            self.p99_never_us,
            self.p99_interval_us,
            self.interval_vs_off_ratio,
            self.p99_always_us,
            self.p99_always_gc_us,
            self.p99_off_concurrent_us,
            self.gc_vs_off_ratio,
            self.group_commit_batches,
            self.recovery_small_records,
            self.recovery_small_wall_s,
            self.recovery_large_records,
            self.recovery_large_wall_s,
            self.recovery_large_records_per_s,
            self.recovery_sharded_records,
            self.recovery_sharded_wall_s,
        )
    }
}

fn sched_cfg() -> SchedulerConfig {
    SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
}

/// A fresh admission-only daemon: `speedup = 0` pins virtual time at
/// zero, so no pacing or dispatch work pollutes the per-request timing.
fn admission_daemon(durability: Option<DurabilityConfig>) -> Arc<Daemon> {
    Daemon::new(
        topology::tx2500(),
        sched_cfg(),
        DaemonConfig {
            speedup: 0.0,
            retire_grace_secs: None,
            history_cap: None,
            durability,
            ..DaemonConfig::default()
        },
    )
}

/// Submit `n` individual jobs one RPC at a time, recording each request's
/// wall latency. Returns the p99 in microseconds.
fn admission_p99_us(d: &Daemon, n: usize, all_acked: &mut bool) -> f64 {
    let mut lat_us = Vec::with_capacity(n);
    for i in 0..n {
        let user = 1 + (i as u32 % 5);
        let t0 = Instant::now();
        let resp = d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, user).with_run_secs(600.0),
        ));
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
        *all_acked &= matches!(resp, Response::SubmitAck(_));
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    percentile(&lat_us, 0.99)
}

/// Best (minimum) admission p99 over `iters` fresh daemons under `fsync`
/// (`None` = journal off). Each journaling iteration gets its own
/// temporary directory.
fn policy_p99_us(
    cfg: &JournalScalingConfig,
    fsync: Option<FsyncPolicy>,
    all_acked: &mut bool,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..cfg.iters.max(1) {
        let tmp;
        let durability = match fsync {
            Some(policy) => {
                tmp = TempDir::new("spotcloud-bench-journal");
                Some(DurabilityConfig::new(tmp.path()).with_fsync(policy))
            }
            None => None,
        };
        let d = admission_daemon(durability);
        best = best.min(admission_p99_us(&d, cfg.jobs, all_acked));
        d.with_scheduler(|s| s.check_invariants().expect("invariants after admissions"));
    }
    best
}

/// Concurrent per-RPC admissions from `threads` writers against one
/// daemon; p99 across every request (µs). This is the group-commit shape:
/// with several acks in flight under `fsync=always`, the parked-writer
/// protocol batches them into shared leader fsyncs.
fn concurrent_p99_us(d: &Arc<Daemon>, n: usize, threads: usize, all_acked: &mut bool) -> f64 {
    let per = (n / threads.max(1)).max(1);
    let mut handles = Vec::new();
    for t in 0..threads.max(1) {
        let d = Arc::clone(d);
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::with_capacity(per);
            let mut ok = true;
            for i in 0..per {
                let user = 1 + ((t * per + i) as u32 % 5);
                let t0 = Instant::now();
                let resp = d.handle(Request::Submit(
                    SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, user)
                        .with_run_secs(600.0),
                ));
                lat.push(t0.elapsed().as_secs_f64() * 1e6);
                ok &= matches!(resp, Response::SubmitAck(_));
            }
            (lat, ok)
        }));
    }
    let mut lat_us = Vec::new();
    for h in handles {
        let (lat, ok) = h.join().expect("writer thread");
        lat_us.extend(lat);
        *all_acked &= ok;
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    percentile(&lat_us, 0.99)
}

/// Best concurrent p99 over `iters` fresh daemons; for journaling runs,
/// also the realized group-commit batch count of the best iteration (via
/// the `STATS` journal block, so the wire plumbing is exercised too).
fn gc_policy_p99_us(
    cfg: &JournalScalingConfig,
    journaled: bool,
    all_acked: &mut bool,
) -> (f64, u64) {
    let mut best = f64::INFINITY;
    let mut batches = 0u64;
    for _ in 0..cfg.iters.max(1) {
        let tmp;
        let durability = if journaled {
            tmp = TempDir::new("spotcloud-bench-journal-gc");
            Some(DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always))
        } else {
            None
        };
        let d = admission_daemon(durability);
        let p99 = concurrent_p99_us(&d, cfg.jobs, cfg.gc_threads, all_acked);
        if p99 < best {
            best = p99;
            batches = match d.handle(Request::Stats) {
                Response::Stats(s) => s.journal.map(|j| j.group_commits).unwrap_or(0),
                _ => 0,
            };
        }
        d.with_scheduler(|s| s.check_invariants().expect("invariants after admissions"));
    }
    (best, batches)
}

/// Grow a journal to `records` admit records (checkpointing pushed past
/// the end so recovery replays every record), drop the daemon, and time
/// `Daemon::recover` cold. Returns (wall seconds, replayed == records).
fn recovery_wall_s(records: usize, all_acked: &mut bool) -> (f64, bool) {
    let tmp = TempDir::new("spotcloud-bench-recovery");
    let dcfg = DurabilityConfig::new(tmp.path())
        .with_fsync(FsyncPolicy::Never)
        .with_checkpoint_every(records as u64 + 1);
    let cfg = DaemonConfig {
        speedup: 0.0,
        retire_grace_secs: None,
        history_cap: None,
        durability: Some(dcfg),
        ..DaemonConfig::default()
    };
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        admission_p99_us(&d, records, all_acked);
        d.shutdown();
    }
    let t0 = Instant::now();
    let (d, report) = Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("recovery");
    let wall = t0.elapsed().as_secs_f64();
    d.with_scheduler(|s| s.check_invariants().expect("invariants after recovery"));
    (wall, report.admits_replayed == records)
}

/// Sharded variant: two scheduler shards (per-shard journals + alloc.log),
/// admissions alternating qos so *both* journals grow, recovery timed
/// cold. Beyond the count, replay must reproduce the writer's job ids
/// *identically* — a sample of the acked ids is probed across both shards.
fn sharded_recovery_wall_s(records: usize, all_acked: &mut bool) -> (f64, bool) {
    let tmp = TempDir::new("spotcloud-bench-recovery-sharded");
    let dcfg = DurabilityConfig::new(tmp.path())
        .with_fsync(FsyncPolicy::Never)
        .with_checkpoint_every(records as u64 + 1);
    let cfg = DaemonConfig {
        speedup: 0.0,
        retire_grace_secs: None,
        history_cap: None,
        durability: Some(dcfg),
        shard_count: 2,
        ..DaemonConfig::default()
    };
    let mut writer_ids = Vec::with_capacity(records);
    {
        let d = Daemon::new(topology::tx2500(), sched_cfg(), cfg.clone());
        for i in 0..records {
            let qos = if i % 2 == 0 {
                QosClass::Normal
            } else {
                QosClass::Spot
            };
            let user = 1 + (i as u32 % 5);
            match d.handle(Request::Submit(
                SubmitSpec::new(qos, JobType::Individual, 1, user).with_run_secs(600.0),
            )) {
                Response::SubmitAck(a) => writer_ids.push(a.first),
                _ => *all_acked = false,
            }
        }
        d.shutdown();
    }
    let t0 = Instant::now();
    let (d, report) =
        Daemon::recover(topology::tx2500(), sched_cfg(), cfg).expect("sharded recovery");
    let wall = t0.elapsed().as_secs_f64();
    let mut ids_match = report.admits_replayed == records;
    let step = (records / 64).max(1);
    for &id in writer_ids.iter().step_by(step) {
        ids_match &= matches!(d.handle(Request::Sjob(id)), Response::Job(_));
    }
    (wall, ids_match)
}

/// Run the scenario.
pub fn run_journal_scaling(cfg: &JournalScalingConfig) -> JournalScalingReport {
    let mut all_acked = true;

    let p99_off_us = policy_p99_us(cfg, None, &mut all_acked);
    let p99_never_us = policy_p99_us(cfg, Some(FsyncPolicy::Never), &mut all_acked);
    let p99_interval_us = policy_p99_us(cfg, Some(FsyncPolicy::default()), &mut all_acked);
    let p99_always_us = policy_p99_us(cfg, Some(FsyncPolicy::Always), &mut all_acked);

    let (p99_off_concurrent_us, _) = gc_policy_p99_us(cfg, false, &mut all_acked);
    let (p99_always_gc_us, group_commit_batches) = gc_policy_p99_us(cfg, true, &mut all_acked);

    let (recovery_small_wall_s, small_match) = recovery_wall_s(cfg.recovery_small, &mut all_acked);
    let (recovery_large_wall_s, large_match) = recovery_wall_s(cfg.recovery_large, &mut all_acked);
    let (recovery_sharded_wall_s, recovery_sharded_ids_match) =
        sharded_recovery_wall_s(cfg.recovery_large, &mut all_acked);

    JournalScalingReport {
        jobs: cfg.jobs,
        p99_off_us,
        p99_never_us,
        p99_interval_us,
        p99_always_us,
        interval_vs_off_ratio: p99_interval_us / p99_off_us.max(f64::EPSILON),
        p99_off_concurrent_us,
        p99_always_gc_us,
        gc_vs_off_ratio: p99_always_gc_us / p99_off_concurrent_us.max(f64::EPSILON),
        group_commit_batches,
        recovery_small_records: cfg.recovery_small,
        recovery_small_wall_s,
        recovery_large_records: cfg.recovery_large,
        recovery_large_wall_s,
        recovery_large_records_per_s: cfg.recovery_large as f64
            / recovery_large_wall_s.max(f64::EPSILON),
        recovery_sharded_records: cfg.recovery_large,
        recovery_sharded_wall_s,
        recovery_sharded_ids_match,
        all_acked,
        replay_counts_match: small_match && large_match,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_journal_scaling_runs_and_reports() {
        let r = run_journal_scaling(&JournalScalingConfig::quick());
        assert!(r.all_acked, "{r:?}");
        assert!(r.replay_counts_match, "{r:?}");
        assert!(r.recovery_sharded_ids_match, "{r:?}");
        assert!(r.p99_off_us > 0.0 && r.p99_off_us.is_finite(), "{r:?}");
        assert!(r.interval_vs_off_ratio > 0.0 && r.interval_vs_off_ratio.is_finite());
        assert!(r.gc_vs_off_ratio > 0.0 && r.gc_vs_off_ratio.is_finite());
        assert!(
            r.group_commit_batches > 0,
            "fsync=always group commit never synced: {r:?}"
        );
        assert!(r.recovery_large_wall_s > 0.0 && r.recovery_large_wall_s.is_finite());
        let json = r.to_json();
        for key in [
            "\"bench\": \"journal_scaling\"",
            "\"p99_off_us\"",
            "\"p99_interval_us\"",
            "\"interval_vs_off_ratio\"",
            "\"p99_always_gc_us\"",
            "\"gc_vs_off_ratio\"",
            "\"recovery_large_records_per_s\"",
            "\"recovery_sharded_ids_match\": true",
            "\"all_acked\": true",
            "\"replay_counts_match\": true",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("journal_scaling"));
    }
}
