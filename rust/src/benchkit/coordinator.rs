//! Mixed read/write/wait contention scenario against an in-process daemon —
//! the CI bench gate's workload.
//!
//! N reader threads hammer `SQUEUE`/`STATS`/`UTIL`/`SJOB`, M writer threads
//! submit and cancel bursts, and K waiter threads block in `WAIT` for their
//! own submissions — the traffic shape of thousands of interactive users
//! sharing one controller (the regime the companion MIT SuperCloud paper
//! measures). The report carries the two numbers the paper's Figure 2
//! plots plus the ones the concurrency refactor is accountable for:
//! requests/sec under contention, read-path wall percentiles (readers must
//! not serialize behind a writer burst), p99 *virtual* scheduling latency,
//! and the scheduler write-lock hold-time percentiles.
//!
//! The `coordinator_mixed` bench target runs this and emits
//! `BENCH_coordinator.json` for the CI artifact trail.

use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::api::{Request, Response, SqueueFilter, SubmitSpec};
use crate::coordinator::{Daemon, DaemonConfig};
use crate::job::{JobType, QosClass};
use crate::metrics::LogHistogram;
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shape of the mixed contention load.
#[derive(Debug, Clone)]
pub struct MixedLoadConfig {
    /// Read-only threads (SQUEUE/STATS/UTIL/SJOB round-robin).
    pub readers: usize,
    /// Mutating threads (burst submit + cancel).
    pub writers: usize,
    /// Threads that submit one interactive job and block in WAIT for it.
    pub waiters: usize,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Jobs per writer submit burst.
    pub submit_batch: u32,
    /// Pause between writer bursts (bounds total job-table growth).
    pub writer_pause: Duration,
    /// Virtual seconds per wall second for the daemon under test.
    pub speedup: f64,
}

impl Default for MixedLoadConfig {
    fn default() -> Self {
        Self {
            readers: 8,
            writers: 2,
            waiters: 4,
            duration: Duration::from_secs(2),
            submit_batch: 16,
            writer_pause: Duration::from_millis(5),
            speedup: 2_000.0,
        }
    }
}

impl MixedLoadConfig {
    /// A sub-second smoke configuration (unit tests, `SPOTCLOUD_BENCH_FAST`).
    pub fn quick() -> Self {
        Self {
            readers: 4,
            writers: 1,
            waiters: 2,
            duration: Duration::from_millis(300),
            submit_batch: 8,
            writer_pause: Duration::from_millis(5),
            speedup: 5_000.0,
        }
    }
}

/// What one mixed-load run measured.
#[derive(Debug, Clone)]
pub struct MixedLoadReport {
    /// Wall-clock run length actually spent.
    pub duration_secs: f64,
    /// Read-only requests completed.
    pub read_ops: u64,
    /// Mutating requests completed (submits + cancels).
    pub write_ops: u64,
    /// WAIT round trips completed.
    pub wait_ops: u64,
    /// WAITs that hit their timeout (should be 0 in a healthy run).
    pub timed_out_waits: u64,
    /// All requests per wall second.
    pub reqs_per_sec: f64,
    /// Wall latency of read-path requests (ns).
    pub read_wall: LogHistogram,
    /// Wall latency of write-path requests (ns).
    pub write_wall: LogHistogram,
    /// p50 of the daemon's virtual scheduling latency histogram (ns).
    pub sched_latency_p50_ns: u64,
    /// p99 of the daemon's virtual scheduling latency histogram (ns) —
    /// the paper's Figure-2 metric under contention.
    pub sched_latency_p99_ns: u64,
    /// p99 wall time the scheduler write mutex was held (ns).
    pub lock_hold_p99_ns: u64,
    /// Snapshot-served requests, from the daemon's lock-path counters.
    pub read_path_ops: u64,
    /// Scheduler-mutex acquisitions, from the daemon's lock-path counters.
    pub write_locks: u64,
    /// WAITs that parked on the completion hub.
    pub waits_parked: u64,
    /// Parked WAITs that resolved. Equal to `waits_parked` after a clean
    /// run: every waiter wakes exactly once.
    pub waits_resumed: u64,
}

impl MixedLoadReport {
    /// The machine-readable record CI uploads (`BENCH_coordinator.json`).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"coordinator_mixed\",\n",
                "  \"duration_secs\": {:.3},\n",
                "  \"read_ops\": {},\n",
                "  \"write_ops\": {},\n",
                "  \"wait_ops\": {},\n",
                "  \"timed_out_waits\": {},\n",
                "  \"reqs_per_sec\": {:.1},\n",
                "  \"read_wall_p50_ns\": {},\n",
                "  \"read_wall_p99_ns\": {},\n",
                "  \"write_wall_p50_ns\": {},\n",
                "  \"write_wall_p99_ns\": {},\n",
                "  \"sched_latency_p50_ns\": {},\n",
                "  \"sched_latency_p99_ns\": {},\n",
                "  \"lock_hold_p99_ns\": {},\n",
                "  \"read_path_ops\": {},\n",
                "  \"write_locks\": {},\n",
                "  \"waits_parked\": {},\n",
                "  \"waits_resumed\": {}\n",
                "}}\n",
            ),
            self.duration_secs,
            self.read_ops,
            self.write_ops,
            self.wait_ops,
            self.timed_out_waits,
            self.reqs_per_sec,
            self.read_wall.p50(),
            self.read_wall.p99(),
            self.write_wall.p50(),
            self.write_wall.p99(),
            self.sched_latency_p50_ns,
            self.sched_latency_p99_ns,
            self.lock_hold_p99_ns,
            self.read_path_ops,
            self.write_locks,
            self.waits_parked,
            self.waits_resumed,
        )
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "coordinator_mixed: {:.0} req/s over {:.2}s (reads={} writes={} waits={} \
             timed_out={}) read_p99={}ns write_p99={}ns sched_p99={}ns lock_hold_p99={}ns",
            self.reqs_per_sec,
            self.duration_secs,
            self.read_ops,
            self.write_ops,
            self.wait_ops,
            self.timed_out_waits,
            self.read_wall.p99(),
            self.write_wall.p99(),
            self.sched_latency_p99_ns,
            self.lock_hold_p99_ns,
        )
    }
}

struct SharedCounters {
    stop: AtomicBool,
    read_ops: AtomicU64,
    write_ops: AtomicU64,
    wait_ops: AtomicU64,
    timed_out_waits: AtomicU64,
    read_wall: Mutex<LogHistogram>,
    write_wall: Mutex<LogHistogram>,
}

/// Run the mixed contention scenario against a fresh daemon (its own pacer
/// thread, typed in-process requests — the transport is exercised by the
/// TCP tests; this measures the coordinator core).
pub fn run_mixed_load(cfg: &MixedLoadConfig) -> MixedLoadReport {
    let daemon = Daemon::new(
        topology::tx2500(),
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        DaemonConfig {
            speedup: cfg.speedup,
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let shared = Arc::new(SharedCounters {
        stop: AtomicBool::new(false),
        read_ops: AtomicU64::new(0),
        write_ops: AtomicU64::new(0),
        wait_ops: AtomicU64::new(0),
        timed_out_waits: AtomicU64::new(0),
        read_wall: Mutex::new(LogHistogram::new()),
        write_wall: Mutex::new(LogHistogram::new()),
    });

    let mut threads = Vec::new();
    for r in 0..cfg.readers {
        let d = Arc::clone(&daemon);
        let s = Arc::clone(&shared);
        threads.push(std::thread::spawn(move || {
            let mut local = LogHistogram::new();
            let mut i = r as u64;
            while !s.stop.load(Ordering::Relaxed) {
                let req = match i % 4 {
                    0 => Request::Squeue(SqueueFilter {
                        limit: Some(32),
                        ..Default::default()
                    }),
                    1 => Request::Stats,
                    2 => Request::Util,
                    _ => Request::Sjob(1 + i % 64),
                };
                let t0 = Instant::now();
                let resp = d.handle(req);
                // SJOB of a not-yet-submitted id is a legal NotFound; any
                // other error under pure read load is a bug.
                debug_assert!(
                    !matches!(&resp, Response::Error(e)
                        if e.code != crate::coordinator::api::ErrorCode::NotFound),
                    "read path errored: {resp:?}"
                );
                local.record(t0.elapsed().as_nanos() as u64);
                s.read_ops.fetch_add(1, Ordering::Relaxed);
                i += 1;
            }
            s.read_wall.lock().expect("bench hist").merge(&local);
        }));
    }
    for w in 0..cfg.writers {
        let d = Arc::clone(&daemon);
        let s = Arc::clone(&shared);
        let batch = cfg.submit_batch;
        let pause = cfg.writer_pause;
        threads.push(std::thread::spawn(move || {
            let mut local = LogHistogram::new();
            let user = 100 + w as u32;
            let mut last_first = 0u64;
            let mut i = 0u64;
            while !s.stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                let resp = d.handle(Request::Submit(
                    SubmitSpec::new(QosClass::Spot, JobType::Individual, 1, user)
                        .with_run_secs(20.0)
                        .with_count(batch),
                ));
                local.record(t0.elapsed().as_nanos() as u64);
                s.write_ops.fetch_add(1, Ordering::Relaxed);
                if let Response::SubmitAck(ack) = resp {
                    // Cancel one job of the *previous* burst: exercises the
                    // cancel write path against mostly-dispatched state.
                    if i % 2 == 1 && last_first != 0 {
                        let t1 = Instant::now();
                        let _ = d.handle(Request::Scancel(last_first));
                        local.record(t1.elapsed().as_nanos() as u64);
                        s.write_ops.fetch_add(1, Ordering::Relaxed);
                    }
                    last_first = ack.first;
                }
                i += 1;
                std::thread::sleep(pause);
            }
            s.write_wall.lock().expect("bench hist").merge(&local);
        }));
    }
    for k in 0..cfg.waiters {
        let d = Arc::clone(&daemon);
        let s = Arc::clone(&shared);
        threads.push(std::thread::spawn(move || {
            let user = 1 + k as u32;
            while !s.stop.load(Ordering::Relaxed) {
                let ack = match d.handle(Request::Submit(
                    SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 32, user)
                        .with_run_secs(15.0),
                )) {
                    Response::SubmitAck(a) => a,
                    other => panic!("waiter submit failed: {other:?}"),
                };
                match d.handle(Request::Wait {
                    jobs: vec![ack.first],
                    timeout_secs: 10.0,
                }) {
                    Response::Wait(wr) => {
                        if wr.timed_out {
                            s.timed_out_waits.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    other => panic!("wait failed: {other:?}"),
                }
                s.wait_ops.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(5));
            }
        }));
    }

    let t0 = Instant::now();
    std::thread::sleep(cfg.duration);
    shared.stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().expect("bench thread panicked");
    }
    let duration_secs = t0.elapsed().as_secs_f64();
    daemon.shutdown();
    pacer.join().expect("pacer");

    daemon.with_scheduler(|sched| {
        sched
            .check_invariants()
            .expect("scheduler invariants violated under contention");
    });

    let read_ops = shared.read_ops.load(Ordering::Relaxed);
    let write_ops = shared.write_ops.load(Ordering::Relaxed);
    let wait_ops = shared.wait_ops.load(Ordering::Relaxed);
    let sched_hist = daemon.metrics.sched_latency();
    let read_wall = shared.read_wall.lock().expect("bench hist").clone();
    let write_wall = shared.write_wall.lock().expect("bench hist").clone();
    MixedLoadReport {
        duration_secs,
        read_ops,
        write_ops,
        wait_ops,
        timed_out_waits: shared.timed_out_waits.load(Ordering::Relaxed),
        reqs_per_sec: (read_ops + write_ops + wait_ops) as f64 / duration_secs.max(1e-9),
        read_wall,
        write_wall,
        sched_latency_p50_ns: sched_hist.p50(),
        sched_latency_p99_ns: sched_hist.p99(),
        lock_hold_p99_ns: daemon.metrics.lock_hold().p99(),
        read_path_ops: daemon.metrics.read_path_ops.load(Ordering::Relaxed),
        write_locks: daemon.metrics.write_locks.load(Ordering::Relaxed),
        waits_parked: daemon.metrics.waits_parked.load(Ordering::Relaxed),
        waits_resumed: daemon.metrics.waits_resumed.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_mixed_load_runs_and_reports() {
        let r = run_mixed_load(&MixedLoadConfig::quick());
        assert!(r.read_ops > 0, "{r:?}");
        assert!(r.write_ops > 0, "{r:?}");
        assert!(r.wait_ops > 0, "{r:?}");
        assert!(r.reqs_per_sec > 0.0);
        assert!(r.read_path_ops >= r.read_ops, "reads must be snapshot-served");
        assert_eq!(r.waits_parked, r.waits_resumed, "exactly-once wake broken");
        let json = r.to_json();
        for key in [
            "\"reqs_per_sec\"",
            "\"read_wall_p99_ns\"",
            "\"sched_latency_p99_ns\"",
            "\"lock_hold_p99_ns\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(r.summary().contains("coordinator_mixed"));
    }
}
