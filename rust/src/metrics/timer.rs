//! Wall-clock timing helpers for the daemon and benchmarks.

use std::time::Instant;

/// Measures wall-clock time from construction and reports on drop via a
/// callback. Useful for instrumenting scheduler hot paths without littering
/// them with explicit start/stop pairs.
pub struct ScopedTimer<F: FnMut(u64)> {
    start: Instant,
    on_done: F,
}

impl<F: FnMut(u64)> ScopedTimer<F> {
    /// Start timing; `on_done` receives elapsed nanoseconds at drop.
    pub fn new(on_done: F) -> Self {
        Self {
            start: Instant::now(),
            on_done,
        }
    }

    /// Elapsed nanoseconds so far.
    pub fn elapsed_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }
}

impl<F: FnMut(u64)> Drop for ScopedTimer<F> {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as u64;
        (self.on_done)(ns);
    }
}

/// Time a closure, returning (result, elapsed seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn scoped_timer_fires_on_drop() {
        let recorded = Cell::new(0u64);
        {
            let _t = ScopedTimer::new(|ns| recorded.set(ns));
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(recorded.get() >= 1_000_000, "recorded {}", recorded.get());
    }

    #[test]
    fn time_it_returns_result() {
        let (v, secs) = time_it(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(secs >= 0.0);
    }
}
