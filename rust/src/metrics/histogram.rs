//! Log-bucketed latency histogram (HdrHistogram-style, std-only).
//!
//! Values are recorded in nanoseconds into buckets of geometrically growing
//! width (each bucket spans ×2^(1/8), i.e. ~9% relative error), which is
//! plenty for scheduling-latency percentiles spanning microseconds to
//! minutes.

/// Sub-bucket resolution: buckets per octave. 8 → ≤ ~9% quantile error.
const SUBBUCKETS_PER_OCTAVE: usize = 8;
/// Supported range: 1 ns .. ~2^63 ns.
const OCTAVES: usize = 63;
const NBUCKETS: usize = OCTAVES * SUBBUCKETS_PER_OCTAVE + 1;

/// A histogram of `u64` values (typically nanoseconds).
#[derive(Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    min: u64,
    max: u64,
    sum: u128,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Create an empty histogram.
    pub fn new() -> Self {
        Self {
            counts: vec![0; NBUCKETS],
            total: 0,
            min: u64::MAX,
            max: 0,
            sum: 0,
        }
    }

    fn bucket_of(value: u64) -> usize {
        if value == 0 {
            return 0;
        }
        // Position = octave * SUB + sub-octave index from the bits below the
        // leading one.
        let lz = 63 - value.leading_zeros() as usize; // floor(log2(value))
        let frac = if lz == 0 {
            0
        } else {
            // Top SUB bits after the leading bit.
            let shift = lz.saturating_sub(3); // log2(SUBBUCKETS_PER_OCTAVE)=3
            ((value >> shift) & (SUBBUCKETS_PER_OCTAVE as u64 - 1)) as usize
        };
        (lz * SUBBUCKETS_PER_OCTAVE + frac).min(NBUCKETS - 1)
    }

    /// Lower edge of a bucket (inverse of `bucket_of`, approximate).
    fn bucket_low(bucket: usize) -> u64 {
        if bucket == 0 {
            return 0;
        }
        let octave = bucket / SUBBUCKETS_PER_OCTAVE;
        let sub = bucket % SUBBUCKETS_PER_OCTAVE;
        if octave >= 63 {
            return u64::MAX;
        }
        let base = 1u64 << octave;
        if octave < 3 {
            base
        } else {
            base + ((sub as u64) << (octave - 3))
        }
    }

    /// Record one value.
    pub fn record(&mut self, value: u64) {
        self.counts[Self::bucket_of(value)] += 1;
        self.total += 1;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
        self.sum += value as u128;
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Arithmetic mean of recorded values.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate quantile (`q` in `[0,1]`). Returns the lower edge of the
    /// bucket containing the q-th value, clamped to observed min/max.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_low(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// p50 shorthand.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// p90 shorthand.
    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    /// p99 shorthand.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        if other.total > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// One-line human summary, treating values as nanoseconds.
    pub fn summary_ns(&self) -> String {
        use crate::util::fmt::fmt_seconds;
        let s = |ns: u64| fmt_seconds(ns as f64 / 1e9);
        format!(
            "n={} min={} p50={} p90={} p99={} max={} mean={}",
            self.total,
            s(self.min()),
            s(self.p50()),
            s(self.p90()),
            s(self.p99()),
            s(self.max()),
            s(self.mean() as u64)
        )
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "LogHistogram({})", self.summary_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_value() {
        let mut h = LogHistogram::new();
        h.record(1000);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 1000);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.p50(), 1000); // clamped to min..max
    }

    #[test]
    fn quantiles_bounded_relative_error() {
        let mut h = LogHistogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, expect) in [(0.5, 50_000.0), (0.9, 90_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < 0.15, "q={q}: got {got}, want ~{expect} (rel {rel})");
        }
    }

    #[test]
    fn mean_exact() {
        let mut h = LogHistogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(100);
        b.record(10_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 100);
        assert_eq!(a.max(), 10_000);
    }

    #[test]
    fn monotone_quantiles() {
        let mut h = LogHistogram::new();
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        for _ in 0..10_000 {
            h.record(rng.gen_range(1, 1_000_000));
        }
        let qs: Vec<u64> = [0.1, 0.25, 0.5, 0.75, 0.9, 0.99]
            .iter()
            .map(|&q| h.quantile(q))
            .collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]), "{qs:?}");
    }

    #[test]
    fn extreme_values_do_not_panic() {
        let mut h = LogHistogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), u64::MAX);
    }
}
