//! Exact summary statistics over small sample sets.
//!
//! The benchmark harness keeps raw samples (iterations are bounded), so we
//! can report exact percentiles; the [`super::histogram::LogHistogram`] is
//! for unbounded streams (the daemon).

/// Exact summary of a sample set of f64 values (seconds, ratios, …).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n<2).
    pub stdev: f64,
    /// Median (p50, linear interpolation).
    pub p50: f64,
    /// p90.
    pub p90: f64,
    /// p99.
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty slice.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n < 2 {
            0.0
        } else {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        };
        Some(Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            stdev: var.sqrt(),
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        })
    }

    /// Coefficient of variation (stdev/mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stdev / self.mean
        }
    }
}

/// Linear-interpolated percentile of a **sorted** slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean; ignores non-positive entries (returns None if none valid).
pub fn geomean(xs: &[f64]) -> Option<f64> {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        None
    } else {
        Some((logs.iter().sum::<f64>() / logs.len() as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[3.0]).unwrap();
        assert_eq!(s.n, 1);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.stdev, 0.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.stdev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [10.0, 20.0];
        assert_eq!(percentile(&sorted, 0.5), 15.0);
        assert_eq!(percentile(&sorted, 0.0), 10.0);
        assert_eq!(percentile(&sorted, 1.0), 20.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]).unwrap() - 10.0).abs() < 1e-9);
        assert!(geomean(&[0.0, -1.0]).is_none());
    }

    #[test]
    fn order_invariant() {
        let a = Summary::of(&[5.0, 1.0, 3.0]).unwrap();
        let b = Summary::of(&[1.0, 3.0, 5.0]).unwrap();
        assert_eq!(a, b);
    }
}
