//! Measurement substrates: latency histograms, summary statistics, timers.

pub mod histogram;
pub mod stats;
pub mod timer;

pub use histogram::LogHistogram;
pub use stats::Summary;
pub use timer::ScopedTimer;
