//! Cluster substrate: nodes, partitions, topologies, and core allocation.
//!
//! Models the hardware side of the paper's systems: the TX-2500 development
//! cluster (19 nodes × 32 cores = 608 cores) and the TX-Green production
//! reservation (64 Intel Xeon Phi nodes × 64 cores = 4096 cores), plus the
//! full TX-Green for scale tests.

pub mod node;
pub mod partition;
pub mod topology;

pub use node::{Node, NodeId, NodeState};
pub use partition::{Partition, PartitionId, PartitionLayout};

use crate::job::JobId;
use std::collections::BTreeMap;

/// A concrete allocation: cores taken on specific nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    /// (node, cores taken on that node) pairs.
    pub slices: Vec<(NodeId, u32)>,
}

impl Allocation {
    /// Total cores in the allocation.
    pub fn cores(&self) -> u32 {
        self.slices.iter().map(|(_, c)| c).sum()
    }

    /// Number of distinct nodes.
    pub fn node_count(&self) -> usize {
        self.slices.len()
    }
}

/// What a job asks the cluster for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocRequest {
    /// `cores` anywhere (packed onto nodes first-fit). Used by individual
    /// and array jobs (core-based scheduling).
    Cores(u32),
    /// `nodes` whole nodes (node-based scheduling, used by triple-mode
    /// jobs: every core of each node is taken).
    WholeNodes(u32),
}

impl AllocRequest {
    /// Cores this request will consume on the given cluster (whole-node
    /// requests depend on the node size).
    pub fn cores_on(&self, cluster: &Cluster) -> u32 {
        match *self {
            AllocRequest::Cores(c) => c,
            AllocRequest::WholeNodes(n) => n * cluster.cores_per_node(),
        }
    }
}

/// The cluster: a set of nodes plus the job→allocation table.
#[derive(Debug, Clone)]
pub struct Cluster {
    nodes: Vec<Node>,
    allocations: BTreeMap<JobId, Allocation>,
    /// First-fit scan hint: every node below this index had zero free cores
    /// the last time it was examined. Purely an optimization — releases and
    /// cleanup transitions move it back down.
    scan_hint: usize,
}

impl Cluster {
    /// Build a homogeneous cluster of `n_nodes` nodes with `cores` each.
    pub fn homogeneous(n_nodes: u32, cores: u32) -> Self {
        let nodes = (0..n_nodes).map(|i| Node::new(NodeId(i), cores)).collect();
        Self {
            nodes,
            allocations: BTreeMap::new(),
            scan_hint: 0,
        }
    }

    /// All nodes.
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Mutable access to one node (scheduler-internal: cleanup/drain).
    pub(crate) fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    /// Mutable node access for failure-injection tests (drain/undrain).
    pub fn node_mut_for_tests(&mut self, idx: u32) -> &mut Node {
        &mut self.nodes[idx as usize]
    }

    /// Node count.
    pub fn node_count(&self) -> u32 {
        self.nodes.len() as u32
    }

    /// Cores per node (panics on heterogeneous clusters; the paper's test
    /// systems are homogeneous within a partition).
    pub fn cores_per_node(&self) -> u32 {
        let c = self.nodes.first().map(|n| n.cores).unwrap_or(0);
        debug_assert!(self.nodes.iter().all(|n| n.cores == c));
        c
    }

    /// Total cores.
    pub fn total_cores(&self) -> u32 {
        self.nodes.iter().map(|n| n.cores).sum()
    }

    /// Currently idle cores.
    pub fn idle_cores(&self) -> u32 {
        self.nodes.iter().map(|n| n.free_cores()).sum()
    }

    /// Number of *fully idle* nodes (the cron agent's reserve is measured in
    /// whole nodes, matching the paper).
    pub fn idle_node_count(&self) -> u32 {
        self.nodes.iter().filter(|n| n.is_idle()).count() as u32
    }

    /// Utilization in [0,1].
    pub fn utilization(&self) -> f64 {
        let total = self.total_cores();
        if total == 0 {
            0.0
        } else {
            1.0 - self.idle_cores() as f64 / total as f64
        }
    }

    /// Whether `req` could be satisfied right now (without preemption).
    pub fn can_allocate(&self, req: AllocRequest) -> bool {
        match req {
            AllocRequest::Cores(c) => self.idle_cores() >= c,
            AllocRequest::WholeNodes(n) => self.idle_node_count() >= n,
        }
    }

    /// Try to allocate for `job`. First-fit over nodes in id order (matches
    /// Slurm's default weighting for a homogeneous partition). Returns the
    /// allocation or None if resources are insufficient.
    pub fn allocate(&mut self, job: JobId, req: AllocRequest) -> Option<Allocation> {
        assert!(
            !self.allocations.contains_key(&job),
            "job {job:?} already has an allocation"
        );
        if !self.can_allocate(req) {
            return None;
        }
        let mut slices = Vec::new();
        // Advance the first-fit hint past allocation-exhausted nodes. Only
        // fullness caused by allocations counts: those nodes free cores only
        // through `release`, which rewinds the hint. (Cleanup/drained nodes
        // regain capacity without a release, so they never advance it.)
        while self.scan_hint < self.nodes.len()
            && self.nodes[self.scan_hint].used_cores() == self.nodes[self.scan_hint].cores
        {
            self.scan_hint += 1;
        }
        match req {
            AllocRequest::Cores(mut need) => {
                if need == 0 {
                    return None;
                }
                for node in &mut self.nodes[self.scan_hint..] {
                    if need == 0 {
                        break;
                    }
                    let take = node.free_cores().min(need);
                    if take > 0 {
                        node.take(job, take);
                        slices.push((node.id, take));
                        need -= take;
                    }
                }
                debug_assert_eq!(need, 0, "can_allocate said yes");
            }
            AllocRequest::WholeNodes(mut need) => {
                if need == 0 {
                    return None;
                }
                for node in &mut self.nodes[self.scan_hint..] {
                    if need == 0 {
                        break;
                    }
                    if node.is_idle() {
                        let c = node.cores;
                        node.take(job, c);
                        slices.push((node.id, c));
                        need -= 1;
                    }
                }
                debug_assert_eq!(need, 0, "can_allocate said yes");
            }
        }
        let alloc = Allocation { slices };
        self.allocations.insert(job, alloc.clone());
        Some(alloc)
    }

    /// Release a job's allocation. Returns the freed allocation.
    pub fn release(&mut self, job: JobId) -> Option<Allocation> {
        let alloc = self.allocations.remove(&job)?;
        for &(nid, cores) in &alloc.slices {
            self.nodes[nid.0 as usize].give_back(job, cores);
            self.scan_hint = self.scan_hint.min(nid.0 as usize);
        }
        Some(alloc)
    }

    /// The allocation currently held by a job, if any.
    pub fn allocation_of(&self, job: JobId) -> Option<&Allocation> {
        self.allocations.get(&job)
    }

    /// Jobs currently holding allocations.
    pub fn allocated_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.allocations.keys().copied()
    }

    /// Allocations with their jobs, in ascending job-id order. Walking this
    /// is bounded by what actually runs, so hot paths (preemption candidate
    /// scans, backfill shadow profiles) use it instead of scanning the full
    /// job table and re-looking each allocation up.
    pub fn allocations(&self) -> impl Iterator<Item = (JobId, &Allocation)> + '_ {
        self.allocations.iter().map(|(&id, alloc)| (id, alloc))
    }

    /// Invariant check (used by property tests): per-node accounting matches
    /// the allocation table and no node is oversubscribed.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut per_node: BTreeMap<NodeId, u32> = BTreeMap::new();
        for alloc in self.allocations.values() {
            for &(nid, c) in &alloc.slices {
                *per_node.entry(nid).or_default() += c;
            }
        }
        for node in &self.nodes {
            let used = per_node.get(&node.id).copied().unwrap_or(0);
            if used != node.used_cores() {
                return Err(format!(
                    "node {:?}: allocation table says {} cores used, node says {}",
                    node.id,
                    used,
                    node.used_cores()
                ));
            }
            if node.used_cores() > node.cores {
                return Err(format!("node {:?} oversubscribed", node.id));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jid(n: u64) -> JobId {
        JobId(n)
    }

    #[test]
    fn homogeneous_accounting() {
        let c = Cluster::homogeneous(19, 32);
        assert_eq!(c.node_count(), 19);
        assert_eq!(c.total_cores(), 608);
        assert_eq!(c.idle_cores(), 608);
        assert_eq!(c.idle_node_count(), 19);
        assert_eq!(c.utilization(), 0.0);
    }

    #[test]
    fn core_allocation_first_fit() {
        let mut c = Cluster::homogeneous(4, 8);
        let a = c.allocate(jid(1), AllocRequest::Cores(10)).unwrap();
        assert_eq!(a.cores(), 10);
        assert_eq!(a.node_count(), 2); // 8 + 2
        assert_eq!(c.idle_cores(), 22);
        assert_eq!(c.idle_node_count(), 2); // node 1 is mixed
        c.check_invariants().unwrap();
    }

    #[test]
    fn whole_node_allocation_skips_mixed_nodes() {
        let mut c = Cluster::homogeneous(4, 8);
        c.allocate(jid(1), AllocRequest::Cores(1)).unwrap(); // dirties node 0
        let a = c.allocate(jid(2), AllocRequest::WholeNodes(3)).unwrap();
        assert_eq!(a.node_count(), 3);
        assert!(a.slices.iter().all(|&(nid, cores)| nid != NodeId(0) && cores == 8));
        assert!(!c.can_allocate(AllocRequest::WholeNodes(1)));
        c.check_invariants().unwrap();
    }

    #[test]
    fn release_restores_capacity() {
        let mut c = Cluster::homogeneous(2, 4);
        c.allocate(jid(1), AllocRequest::Cores(8)).unwrap();
        assert_eq!(c.idle_cores(), 0);
        assert!(c.allocate(jid(2), AllocRequest::Cores(1)).is_none());
        c.release(jid(1)).unwrap();
        assert_eq!(c.idle_cores(), 8);
        assert_eq!(c.idle_node_count(), 2);
        assert!(c.release(jid(1)).is_none(), "double release returns None");
        c.check_invariants().unwrap();
    }

    #[test]
    fn allocations_iterate_in_job_id_order() {
        let mut c = Cluster::homogeneous(4, 8);
        c.allocate(jid(5), AllocRequest::Cores(3)).unwrap();
        c.allocate(jid(2), AllocRequest::Cores(2)).unwrap();
        let got: Vec<(JobId, u32)> = c.allocations().map(|(id, a)| (id, a.cores())).collect();
        assert_eq!(got, vec![(jid(2), 2), (jid(5), 3)]);
    }

    #[test]
    fn insufficient_resources_refused() {
        let mut c = Cluster::homogeneous(2, 4);
        assert!(c.allocate(jid(1), AllocRequest::Cores(9)).is_none());
        assert!(c.allocate(jid(1), AllocRequest::WholeNodes(3)).is_none());
        assert_eq!(c.idle_cores(), 8, "failed allocation must not leak");
        c.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "already has an allocation")]
    fn double_allocate_panics() {
        let mut c = Cluster::homogeneous(2, 4);
        c.allocate(jid(1), AllocRequest::Cores(1)).unwrap();
        let _ = c.allocate(jid(1), AllocRequest::Cores(1));
    }

    #[test]
    fn zero_requests_refused() {
        let mut c = Cluster::homogeneous(2, 4);
        assert!(c.allocate(jid(1), AllocRequest::Cores(0)).is_none());
        assert!(c.allocate(jid(2), AllocRequest::WholeNodes(0)).is_none());
    }
}
