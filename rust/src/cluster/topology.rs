//! Cluster topology presets matching the paper's test systems.

use super::Cluster;

/// TX-2500 development cluster: 19 nodes × 32 cores = 608 cores (paper
/// Section III.A: "a total of 608 cores, 32 cores per node with 19 nodes").
pub fn tx2500() -> Cluster {
    Cluster::homogeneous(19, 32)
}

/// The TX-Green production experiment reservation: 64 Intel Xeon Phi 7210
/// nodes × 64 cores = 4096 cores, matching the per-user resource limit on
/// that partition (paper Section III.C).
pub fn txgreen_reservation() -> Cluster {
    Cluster::homogeneous(64, 64)
}

/// Full TX-Green KNL partition: 648 nodes × 64 cores = 41,472 cores. Used by
/// scale benchmarks, not by the paper's figures (those ran in the 64-node
/// reservation).
pub fn txgreen_full() -> Cluster {
    Cluster::homogeneous(648, 64)
}

/// The Xeon Gold addition: 225 nodes × 40 cores = 9,000 cores.
pub fn txgreen_gold() -> Cluster {
    Cluster::homogeneous(225, 40)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_core_counts() {
        assert_eq!(tx2500().total_cores(), 608);
        assert_eq!(txgreen_reservation().total_cores(), 4096);
        assert_eq!(txgreen_full().total_cores(), 41_472);
        assert_eq!(txgreen_gold().total_cores(), 9_000);
    }
}
