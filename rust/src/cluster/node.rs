//! A compute node: core accounting and lifecycle state.

use crate::job::JobId;
use std::collections::BTreeMap;

/// Node identifier (index into the cluster's node table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

/// Node lifecycle state (subset of Slurm's node states that matter here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// No cores allocated.
    Idle,
    /// Some but not all cores allocated.
    Mixed,
    /// All cores allocated.
    Allocated,
    /// Undergoing epilog/cleanup after a job was preempted or completed;
    /// cannot accept work until cleanup finishes.
    Cleanup,
    /// Administratively removed from service.
    Drained,
}

/// A compute node.
#[derive(Debug, Clone)]
pub struct Node {
    /// Identifier.
    pub id: NodeId,
    /// Total cores.
    pub cores: u32,
    used: u32,
    /// Per-job core usage on this node.
    jobs: BTreeMap<JobId, u32>,
    drained: bool,
    in_cleanup: bool,
}

impl Node {
    /// A fresh idle node.
    pub fn new(id: NodeId, cores: u32) -> Self {
        assert!(cores > 0, "node must have at least one core");
        Self {
            id,
            cores,
            used: 0,
            jobs: BTreeMap::new(),
            drained: false,
            in_cleanup: false,
        }
    }

    /// Free cores (0 when drained or in cleanup).
    pub fn free_cores(&self) -> u32 {
        if self.drained || self.in_cleanup {
            0
        } else {
            self.cores - self.used
        }
    }

    /// Cores currently allocated.
    pub fn used_cores(&self) -> u32 {
        self.used
    }

    /// True when fully idle and schedulable.
    pub fn is_idle(&self) -> bool {
        self.used == 0 && !self.drained && !self.in_cleanup
    }

    /// Current state.
    pub fn state(&self) -> NodeState {
        if self.drained {
            NodeState::Drained
        } else if self.in_cleanup {
            NodeState::Cleanup
        } else if self.used == 0 {
            NodeState::Idle
        } else if self.used == self.cores {
            NodeState::Allocated
        } else {
            NodeState::Mixed
        }
    }

    /// Jobs running (or holding cores) on this node.
    pub fn jobs(&self) -> impl Iterator<Item = (JobId, u32)> + '_ {
        self.jobs.iter().map(|(&j, &c)| (j, c))
    }

    /// Allocate `cores` to `job`. Panics on oversubscription — the cluster
    /// checks capacity first, so reaching that is a scheduler bug.
    pub(crate) fn take(&mut self, job: JobId, cores: u32) {
        assert!(
            cores <= self.free_cores(),
            "node {:?}: taking {} cores with only {} free",
            self.id,
            cores,
            self.free_cores()
        );
        self.used += cores;
        *self.jobs.entry(job).or_insert(0) += cores;
    }

    /// Return `cores` previously taken by `job`.
    pub(crate) fn give_back(&mut self, job: JobId, cores: u32) {
        let held = self.jobs.get_mut(&job).expect("job not on node");
        assert!(*held >= cores, "returning more cores than held");
        *held -= cores;
        if *held == 0 {
            self.jobs.remove(&job);
        }
        self.used -= cores;
    }

    /// Enter cleanup (epilog running). Remaining allocations stay until
    /// released, but no new work lands.
    pub fn begin_cleanup(&mut self) {
        self.in_cleanup = true;
    }

    /// Cleanup done; node schedulable again.
    pub fn end_cleanup(&mut self) {
        self.in_cleanup = false;
    }

    /// Drain / undrain (admin operations; used in failure-injection tests).
    pub fn set_drained(&mut self, drained: bool) {
        self.drained = drained;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_transitions() {
        let mut n = Node::new(NodeId(0), 4);
        assert_eq!(n.state(), NodeState::Idle);
        n.take(JobId(1), 2);
        assert_eq!(n.state(), NodeState::Mixed);
        n.take(JobId(2), 2);
        assert_eq!(n.state(), NodeState::Allocated);
        n.give_back(JobId(1), 2);
        assert_eq!(n.state(), NodeState::Mixed);
        n.give_back(JobId(2), 2);
        assert_eq!(n.state(), NodeState::Idle);
    }

    #[test]
    fn cleanup_blocks_scheduling() {
        let mut n = Node::new(NodeId(0), 4);
        n.begin_cleanup();
        assert_eq!(n.free_cores(), 0);
        assert!(!n.is_idle());
        assert_eq!(n.state(), NodeState::Cleanup);
        n.end_cleanup();
        assert_eq!(n.free_cores(), 4);
        assert!(n.is_idle());
    }

    #[test]
    fn drained_blocks_scheduling() {
        let mut n = Node::new(NodeId(0), 4);
        n.set_drained(true);
        assert_eq!(n.free_cores(), 0);
        assert_eq!(n.state(), NodeState::Drained);
    }

    #[test]
    #[should_panic(expected = "cores with only")]
    fn oversubscription_panics() {
        // free_cores is 4; taking 5 must panic with a helpful message.
        let mut n = Node::new(NodeId(0), 4);
        n.take(JobId(1), 5);
    }

    #[test]
    fn per_job_tracking() {
        let mut n = Node::new(NodeId(0), 8);
        n.take(JobId(1), 3);
        n.take(JobId(1), 2); // same job takes more
        let jobs: Vec<_> = n.jobs().collect();
        assert_eq!(jobs, vec![(JobId(1), 5)]);
        n.give_back(JobId(1), 5);
        assert_eq!(n.jobs().count(), 0);
    }
}
