//! Partitions: the paper's single vs dual partition configurations.
//!
//! In the paper, Slurm is configured either with one partition serving both
//! normal (interactive) and spot jobs, or with two partitions — one for
//! interactive jobs, one for spot jobs — covering the same nodes. The
//! partition layout does not change the hardware; it changes which pending
//! queue(s) the scheduler walks and how expensive the preemption candidate
//! scan is (see `sim::costs::single_partition_scan_penalty`).

use crate::job::QosClass;

/// Partition identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PartitionId(pub u8);

/// A partition: a named queue admitting certain QoS classes.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Identifier.
    pub id: PartitionId,
    /// Human-readable name (`interactive`, `spot`, `shared`).
    pub name: &'static str,
    /// QoS classes admitted to this partition's queue.
    pub admits: Vec<QosClass>,
}

impl Partition {
    /// Whether a QoS class may be queued here.
    pub fn admits(&self, qos: QosClass) -> bool {
        self.admits.contains(&qos)
    }
}

/// The paper's two cluster configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionLayout {
    /// One partition serves both interactive and spot jobs.
    Single,
    /// Separate partitions for interactive and spot jobs (same nodes).
    Dual,
}

impl PartitionLayout {
    /// Materialize the partition set for this layout.
    pub fn partitions(self) -> Vec<Partition> {
        match self {
            PartitionLayout::Single => vec![Partition {
                id: PartitionId(0),
                name: "shared",
                admits: vec![QosClass::Normal, QosClass::Spot],
            }],
            PartitionLayout::Dual => vec![
                Partition {
                    id: PartitionId(0),
                    name: "interactive",
                    admits: vec![QosClass::Normal],
                },
                Partition {
                    id: PartitionId(1),
                    name: "spot",
                    admits: vec![QosClass::Spot],
                },
            ],
        }
    }

    /// The partition a job of the given QoS is routed to.
    pub fn route(self, qos: QosClass) -> PartitionId {
        match (self, qos) {
            (PartitionLayout::Single, _) => PartitionId(0),
            (PartitionLayout::Dual, QosClass::Normal) => PartitionId(0),
            (PartitionLayout::Dual, QosClass::Spot) => PartitionId(1),
        }
    }

    /// Short label for reports.
    pub fn label(self) -> &'static str {
        match self {
            PartitionLayout::Single => "single",
            PartitionLayout::Dual => "dual",
        }
    }
}

impl std::fmt::Display for PartitionLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_layout_shares_one_queue() {
        let ps = PartitionLayout::Single.partitions();
        assert_eq!(ps.len(), 1);
        assert!(ps[0].admits(QosClass::Normal));
        assert!(ps[0].admits(QosClass::Spot));
        assert_eq!(PartitionLayout::Single.route(QosClass::Spot), PartitionId(0));
    }

    #[test]
    fn dual_layout_separates_queues() {
        let ps = PartitionLayout::Dual.partitions();
        assert_eq!(ps.len(), 2);
        assert!(ps[0].admits(QosClass::Normal));
        assert!(!ps[0].admits(QosClass::Spot));
        assert!(ps[1].admits(QosClass::Spot));
        assert_eq!(PartitionLayout::Dual.route(QosClass::Normal), PartitionId(0));
        assert_eq!(PartitionLayout::Dual.route(QosClass::Spot), PartitionId(1));
    }
}
