//! Minimal property-based testing harness (proptest substitute).
//!
//! Usage (no_run: doctest binaries lack the libxla_extension rpath):
//!
//! ```no_run
//! use spotcloud::testkit::prop::{Prop, Gen};
//!
//! Prop::new("addition commutes")
//!     .cases(200)
//!     .run(|g| {
//!         let a = g.u64(0, 1_000);
//!         let b = g.u64(0, 1_000);
//!         assert_eq!(a + b, b + a);
//!     });
//! ```
//!
//! On failure the harness re-runs the property with progressively smaller
//! draws (halving each numeric draw toward its lower bound) and panics with
//! the failing seed so the case is reproducible.

use crate::util::rng::Xoshiro256;

/// A deterministic draw source handed to properties. Records draws so the
/// shrinker can replay them scaled down.
pub struct Gen {
    rng: Xoshiro256,
    /// Scale in [0,1]: 1.0 = full range, smaller = shrunk toward minimum.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            scale,
        }
    }

    /// u64 in `[lo, hi]` (inclusive), scaled toward `lo` during shrinking.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let raw = self.rng.gen_range(lo, hi + 1);
        lo + ((raw - lo) as f64 * self.scale) as u64
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// f64 in `[lo, hi)`, scaled toward `lo` during shrinking.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (self.rng.uniform(lo, hi) - lo) * self.scale
    }

    /// Boolean with probability `p` of `true`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// A vector of `len` items drawn by `f`; len scales down when shrinking.
    pub fn vec<T>(&mut self, lo_len: usize, hi_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(lo_len, hi_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one of the provided choices.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let i = self.rng.index(xs.len());
        &xs[i]
    }

    /// Access to the raw RNG for custom draws (not shrunk).
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// A property runner.
pub struct Prop {
    name: &'static str,
    cases: u32,
    seed: u64,
}

impl Prop {
    /// Create a property with a descriptive name.
    pub fn new(name: &'static str) -> Self {
        // Default seed derives from the name so distinct properties explore
        // distinct streams but remain reproducible run-to-run.
        let seed = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
        Self {
            name,
            cases: 100,
            seed,
        }
    }

    /// Number of random cases (default 100).
    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    /// Fixed seed override (for reproducing a reported failure).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property; panics with seed + shrink info on failure.
    pub fn run(self, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            if let Err(panic) = Self::attempt(case_seed, 1.0, &prop) {
                // Shrink: find the smallest scale that still fails.
                let mut failing_scale = 1.0f64;
                let mut scale = 0.5f64;
                for _ in 0..16 {
                    if Self::attempt(case_seed, scale, &prop).is_err() {
                        failing_scale = scale;
                        scale *= 0.5;
                    } else {
                        scale = (scale + failing_scale) / 2.0;
                    }
                }
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property '{}' failed (case {}, seed {:#x}, minimal failing scale {:.4}): {}",
                    self.name, case, case_seed, failing_scale, msg
                );
            }
        }
    }

    fn attempt(
        seed: u64,
        scale: f64,
        prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
    ) -> Result<(), Box<dyn std::any::Any + Send>> {
        let prev_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence expected panics
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, scale);
            prop(&mut g);
        });
        std::panic::set_hook(prev_hook);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Prop::new("sum is symmetric").cases(50).run(|g| {
            let a = g.u64(0, 100);
            let b = g.u64(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_panics_with_seed() {
        let result = std::panic::catch_unwind(|| {
            Prop::new("always fails").cases(10).run(|g| {
                let x = g.u64(0, 100);
                assert!(x > 1_000, "x was {x}");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn generators_respect_bounds() {
        Prop::new("bounds").cases(200).run(|g| {
            let x = g.u64(10, 20);
            assert!((10..=20).contains(&x));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec(2, 5, |g| g.usize(0, 9));
            assert!(v.len() >= 2 && v.len() <= 5);
            assert!(v.iter().all(|&i| i <= 9));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed: u64| {
            let mut out = Vec::new();
            let mut g = Gen::new(seed, 1.0);
            for _ in 0..5 {
                out.push(g.u64(0, 1_000_000));
            }
            out
        };
        assert_eq!(collect(77), collect(77));
        assert_ne!(collect(77), collect(78));
    }
}
