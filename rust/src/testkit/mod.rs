//! Testing substrates: a minimal property-based testing harness.
//!
//! `proptest` is unavailable offline, so [`prop`] provides the subset the
//! invariant tests need: seeded generators, a configurable case count, and
//! greedy input shrinking on failure.

pub mod prop;
