//! Testing substrates: a minimal property-based testing harness and the
//! durability crash-injection helpers.
//!
//! `proptest` is unavailable offline, so [`prop`] provides the subset the
//! invariant tests need: seeded generators, a configurable case count, and
//! greedy input shrinking on failure. [`crash`] provides temp-dir plumbing
//! and fault-armed durability configs for the kill-and-recover tests.

pub mod crash;
pub mod prop;
