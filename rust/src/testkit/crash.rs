//! Crash-injection harness for the durability subsystem.
//!
//! Real kill-the-process tests are slow and nondeterministic; instead the
//! journal exposes countdown [`FaultPoint`] arms
//! ([`crate::coordinator::FaultPlan`]): arm a point to fire on its next
//! hit, or `arm_after(point, n)` to let `n` hits pass first — which is how
//! a test crashes *between* shard A's and shard B's append of one
//! cross-shard manifest. Firing fails the operation *and* leaves the
//! on-disk state exactly as a crash at that point would (the pre-fsync
//! point truncates unsynced bytes, the mid-checkpoint point leaves a torn
//! new segment next to the intact old ones, the allocator point tears
//! `alloc.log`). A sharded daemon clones the plan into every shard's
//! journal ([`DurabilityConfig::for_shard`] shares the arms), so one
//! countdown spans all shards in admission order. A test then simply drops
//! the "crashed" daemon and calls `Daemon::recover` on the same directory —
//! same coverage, milliseconds per case.

use crate::coordinator::{DurabilityConfig, FaultPoint, FsyncPolicy};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// A process-unique temporary directory, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

impl TempDir {
    /// Create `<tmp>/<prefix>-<pid>-<seq>` (fresh and empty).
    pub fn new(prefix: &str) -> TempDir {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "{prefix}-{}-{seq}",
            std::process::id()
        ));
        // A stale run's leftovers must not leak into this test.
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the directory.
    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// A durability config whose fault plan has `point` armed — the next time
/// the journal reaches that point it "crashes" (fails and poisons). Uses
/// `fsync` so each fault point can pick the policy that makes its
/// semantics exact (`AfterAppend` wants `Always` so the durable/lost
/// boundary is the previous record).
pub fn faulty_durability(
    dir: impl Into<PathBuf>,
    fsync: FsyncPolicy,
    point: FaultPoint,
) -> DurabilityConfig {
    let cfg = DurabilityConfig::new(dir).with_fsync(fsync);
    cfg.faults.arm(point);
    cfg
}
