//! Virtual time: integer nanoseconds since simulation start.
//!
//! Integer time keeps the event queue totally ordered and the simulation
//! bit-reproducible across platforms (no float drift over long runs).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (nanoseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    /// t = 0.
    pub const ZERO: SimTime = SimTime(0);
    /// The end of time (useful as an "infinity" sentinel).
    pub const MAX: SimTime = SimTime(u64::MAX);

    /// From whole seconds.
    pub const fn from_secs(s: u64) -> Self {
        SimTime(s * 1_000_000_000)
    }

    /// From milliseconds.
    pub const fn from_millis(ms: u64) -> Self {
        SimTime(ms * 1_000_000)
    }

    /// From microseconds.
    pub const fn from_micros(us: u64) -> Self {
        SimTime(us * 1_000)
    }

    /// From fractional seconds (rounds to nearest ns; saturates at MAX).
    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0, "negative duration");
        if s >= u64::MAX as f64 / 1e9 {
            SimTime::MAX
        } else {
            SimTime((s * 1e9).round() as u64)
        }
    }

    /// As fractional seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Raw nanoseconds.
    pub fn as_nanos(self) -> u64 {
        self.0
    }

    /// Saturating subtraction (durations never go negative).
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }

    /// Round **up** to the next multiple of `period` strictly after `self`.
    /// Models "wait for the next scheduler cycle boundary".
    pub fn next_boundary(self, period: SimTime) -> SimTime {
        assert!(period.0 > 0, "zero period");
        let k = self.0 / period.0 + 1;
        SimTime(k * period.0)
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_add(rhs.0).expect("SimTime overflow"))
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        *self = *self + rhs;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("SimTime underflow"))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::fmt::fmt_seconds(self.as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(SimTime::from_secs(3).as_secs_f64(), 3.0);
        assert_eq!(SimTime::from_millis(1500), SimTime::from_micros(1_500_000));
        assert_eq!(SimTime::from_secs_f64(0.25).as_nanos(), 250_000_000);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_secs(2);
        let b = SimTime::from_secs(1);
        assert_eq!(a + b, SimTime::from_secs(3));
        assert_eq!(a - b, SimTime::from_secs(1));
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = SimTime::from_secs(1) - SimTime::from_secs(2);
    }

    #[test]
    fn next_boundary_strictly_after() {
        let period = SimTime::from_secs(60);
        // exactly on a boundary moves to the NEXT one
        assert_eq!(SimTime::from_secs(60).next_boundary(period), SimTime::from_secs(120));
        assert_eq!(SimTime::from_secs(61).next_boundary(period), SimTime::from_secs(120));
        assert_eq!(SimTime::ZERO.next_boundary(period), SimTime::from_secs(60));
    }

    #[test]
    fn display_uses_units() {
        assert_eq!(format!("{}", SimTime::from_millis(12)), "12.000 ms");
    }

    #[test]
    fn from_secs_f64_saturates() {
        assert_eq!(SimTime::from_secs_f64(1e30), SimTime::MAX);
    }
}
