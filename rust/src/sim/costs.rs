//! The calibrated scheduler latency cost model.
//!
//! Every latency the simulated scheduler charges is a named constant here,
//! with the rationale recorded. Two presets are provided:
//!
//! * [`SchedCosts::dedicated`] — an idle development cluster (the paper's
//!   TX-2500, and TX-Green during the monthly maintenance window used for
//!   the Fig 2g runs): short cycle periods, no background queue.
//! * [`SchedCosts::production`] — the loaded TX-Green production system:
//!   longer effective cycle periods, a background pending queue that the
//!   main/backfill cycles must walk, and slower node cleanup.
//!
//! Calibration anchors (paper, Section III):
//!
//! * Baseline triple-mode 4096-task job dispatches in ~0.5 s
//!   (≈1.2e-4 s/task); individual/array are ≥100× slower per task
//!   (≈1e-2 s/task) — anchored by `dispatch_per_task` and
//!   `per_job_overhead`.
//! * Automatic QoS preemption degrades triple-mode scheduling by ~3 orders
//!   of magnitude on production (0.5 s → ~minutes) — anchored by the cycle
//!   waits (`main_cycle_period`, `backfill_cycle_period`), `requeue
//!   transaction`, and `node_epilog` charged on the preemption path.
//! * Manual (requeue-before-submit) preemption: individual/array ≈ baseline;
//!   triple-mode ≈ 5 s total — anchored by `requeue_transaction` +
//!   `node_epilog` being the only added terms.
//! * Slurm operational magnitudes: `sched_interval` default 60 s,
//!   `bf_interval` default 30 s, RPC round-trips in the low milliseconds,
//!   epilog cleanup seconds to tens of seconds on busy KNL nodes.

use super::time::SimTime;

/// Calibrated latency constants for the simulated scheduler.
#[derive(Debug, Clone)]
pub struct SchedCosts {
    // ---- submission path -------------------------------------------------
    /// `sbatch` → controller RPC + job-record creation. Charged once per
    /// submitted job before the scheduler can see it.
    pub submit_rpc: SimTime,

    // ---- scheduling cycles ----------------------------------------------
    /// Period of the *periodic* main scheduling cycle (Slurm
    /// `sched_interval`). A submit also triggers an immediate main-cycle
    /// attempt after `submit_trigger_delay`.
    pub main_cycle_period: SimTime,
    /// Delay between a submission and the submit-triggered main cycle pass
    /// (controller lock acquisition + queue insertion).
    pub submit_trigger_delay: SimTime,
    /// Period of the backfill cycle (Slurm `bf_interval`).
    pub backfill_cycle_period: SimTime,
    /// Cost charged per pending job examined by the main cycle.
    pub main_per_job: SimTime,
    /// Cost charged per candidate examined by the backfill cycle (shadow
    /// reservation computation makes this much heavier than the main cycle).
    pub backfill_per_job: SimTime,
    /// Fixed cost of one backfill pass (shadow map construction), even with
    /// an empty queue.
    pub backfill_pass_base: SimTime,
    /// Maximum pending candidates one backfill pass examines (Slurm's
    /// `bf_max_job_test`). Real controllers never walk a 100k-deep queue in
    /// one backfill cycle; without this cap a large burst makes every
    /// backfill pass O(queue) in both virtual and wall time.
    pub bf_max_job_test: usize,
    /// Number of unrelated pending jobs ahead of ours in the production
    /// queue (background load). Zero on a dedicated system.
    pub background_queue_depth: u32,

    // ---- dispatch path ---------------------------------------------------
    /// Fixed per-job scheduling/allocation transaction (allocation record,
    /// credential minting, prolog kick-off). Individual jobs pay this per
    /// job; array jobs pay it once per array.
    pub per_job_overhead: SimTime,
    /// Per-task dispatch RPC (controller → slurmd launch). Array tasks and
    /// individual jobs pay this per task — each array task materializes a
    /// full job record when scheduled, which is why this is expensive.
    pub dispatch_per_task: SimTime,
    /// Per-node-script dispatch for triple-mode jobs: one node-level launch
    /// RPC per consolidated script, much lighter than a per-task job-record
    /// transaction. This asymmetry (plus the 64:1 consolidation) produces
    /// the paper's ≥100× triple-mode launch advantage.
    pub dispatch_per_node_script: SimTime,
    /// Extra fixed cost for a triple-mode launch (the consolidation wrapper
    /// script setup by gridMatlab/LLMapReduce tooling).
    pub triple_mode_setup: SimTime,

    // ---- preemption path -------------------------------------------------
    /// Scanning QoS preemption candidates: fixed base cost.
    pub preempt_scan_base: SimTime,
    /// Scanning QoS preemption candidates: cost per running spot job
    /// examined.
    pub preempt_scan_per_job: SimTime,
    /// A requeue/cancel transaction for one preempted job (state save,
    /// signal fan-out to its nodes, re-queue bookkeeping).
    pub requeue_transaction: SimTime,
    /// Node cleanup (epilog + health check) before a preempted node can be
    /// reallocated.
    pub node_epilog: SimTime,
    /// Extra queue-scan penalty charged per scheduling cycle when interactive
    /// and spot jobs share a single partition (the scheduler re-examines the
    /// mixed queue under one partition lock). Explains single > dual cost.
    pub single_partition_scan_penalty: SimTime,
    /// Number of *additional* scheduling cycles the scheduler-driven
    /// automatic preemption path waits before the preempting job is
    /// re-examined after its preemption request (Slurm defers the job and
    /// only retries allocation on a later cycle; on production the retry is
    /// regularly pushed to the backfill cycle).
    pub auto_preempt_retry_cycles: u32,

    // ---- cron agent (the paper's contribution) ---------------------------
    /// Cron agent wake-up period (the paper uses a 1-minute crontab).
    pub cron_interval: SimTime,
    /// Cost of one cron-agent pass: querying the scheduler state (squeue /
    /// sinfo equivalents) and updating the spot QoS MaxTRESPerUser.
    pub cron_pass_overhead: SimTime,
}

impl SchedCosts {
    /// Idle/dedicated cluster (paper's TX-2500 development system and the
    /// maintenance-window TX-Green runs).
    pub fn dedicated() -> Self {
        Self {
            submit_rpc: SimTime::from_millis(5),
            main_cycle_period: SimTime::from_secs(15),
            submit_trigger_delay: SimTime::from_millis(20),
            backfill_cycle_period: SimTime::from_secs(30),
            main_per_job: SimTime::from_micros(500),
            backfill_per_job: SimTime::from_millis(5),
            backfill_pass_base: SimTime::from_millis(300),
            bf_max_job_test: 1000,
            background_queue_depth: 0,
            per_job_overhead: SimTime::from_millis(2),
            dispatch_per_task: SimTime::from_millis(10),
            dispatch_per_node_script: SimTime::from_millis(2),
            triple_mode_setup: SimTime::from_millis(10),
            preempt_scan_base: SimTime::from_millis(20),
            preempt_scan_per_job: SimTime::from_millis(2),
            requeue_transaction: SimTime::from_millis(300),
            node_epilog: SimTime::from_secs(2),
            single_partition_scan_penalty: SimTime::from_millis(200),
            auto_preempt_retry_cycles: 1,
            cron_interval: SimTime::from_secs(60),
            cron_pass_overhead: SimTime::from_millis(150),
        }
    }

    /// Loaded production cluster (paper's TX-Green).
    pub fn production() -> Self {
        Self {
            submit_rpc: SimTime::from_millis(15),
            // On production, the effective period between cycles that will
            // actually pick our job back up is dominated by Slurm's default
            // sched_interval=60s plus controller contention.
            main_cycle_period: SimTime::from_secs(60),
            submit_trigger_delay: SimTime::from_millis(50),
            backfill_cycle_period: SimTime::from_secs(30),
            main_per_job: SimTime::from_millis(1),
            backfill_per_job: SimTime::from_millis(20),
            backfill_pass_base: SimTime::from_secs(1),
            bf_max_job_test: 1000,
            background_queue_depth: 200,
            per_job_overhead: SimTime::from_millis(2),
            dispatch_per_task: SimTime::from_millis(10),
            dispatch_per_node_script: SimTime::from_millis(5),
            triple_mode_setup: SimTime::from_millis(20),
            preempt_scan_base: SimTime::from_millis(100),
            preempt_scan_per_job: SimTime::from_millis(5),
            requeue_transaction: SimTime::from_millis(500),
            node_epilog: SimTime::from_secs(4),
            single_partition_scan_penalty: SimTime::from_secs(2),
            auto_preempt_retry_cycles: 5,
            cron_interval: SimTime::from_secs(60),
            cron_pass_overhead: SimTime::from_millis(300),
        }
    }

    /// Dispatch cost for `n_dispatches` launch RPCs plus per-job overhead.
    /// Triple-mode launches use the lighter per-node-script RPC.
    pub fn dispatch_cost(&self, n_dispatches: u64, triple_mode: bool) -> SimTime {
        let per = if triple_mode {
            self.dispatch_per_node_script.0
        } else {
            self.dispatch_per_task.0
        };
        SimTime(self.per_job_overhead.0 + per * n_dispatches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_triple_mode_anchor() {
        // 4096 tasks at 64/node = 64 node scripts. Baseline triple-mode total
        // should be ~0.5s per the paper.
        let c = SchedCosts::production();
        let total = c.dispatch_cost(64, true) + c.triple_mode_setup;
        let secs = total.as_secs_f64();
        assert!((0.1..1.5).contains(&secs), "triple-mode anchor: {secs}");
    }

    #[test]
    fn baseline_array_anchor() {
        // 4096-task array: ~1e-2 s/task → ~41s total; must be ≥100× the
        // per-task cost of triple mode.
        let c = SchedCosts::production();
        let array_total = c.dispatch_cost(4096, false).as_secs_f64();
        let triple_total = (c.dispatch_cost(64, true) + c.triple_mode_setup).as_secs_f64();
        let per_task_ratio = (array_total / 4096.0) / (triple_total / 4096.0);
        assert!(per_task_ratio >= 100.0, "ratio {per_task_ratio}");
        assert!((20.0..120.0).contains(&array_total), "array total {array_total}");
    }

    #[test]
    fn production_slower_than_dedicated() {
        let d = SchedCosts::dedicated();
        let p = SchedCosts::production();
        assert!(p.node_epilog > d.node_epilog);
        assert!(p.background_queue_depth > d.background_queue_depth);
        assert!(p.main_cycle_period >= d.main_cycle_period);
    }
}
