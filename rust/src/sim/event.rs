//! Deterministic timed event queue.
//!
//! A binary heap keyed by `(time, sequence)` — the sequence number breaks
//! ties in insertion order so simulations are fully deterministic regardless
//! of payload type.

use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse so the BinaryHeap (max-heap) pops the EARLIEST entry.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// A time-ordered queue of events of type `E`.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Create an empty queue.
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }

    /// Schedule `payload` at absolute time `at`.
    pub fn push(&mut self, at: SimTime, payload: E) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { at, seq, payload });
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| (e.at, e.payload))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs(3), "c");
        q.push(SimTime::from_secs(1), "a");
        q.push(SimTime::from_secs(2), "b");
        assert_eq!(q.pop(), Some((SimTime::from_secs(1), "a")));
        assert_eq!(q.pop(), Some((SimTime::from_secs(2), "b")));
        assert_eq!(q.pop(), Some((SimTime::from_secs(3), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        let t = SimTime::from_secs(5);
        for i in 0..10 {
            q.push(t, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(SimTime::from_secs(9), ());
        q.push(SimTime::from_secs(4), ());
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(4)));
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs(10), 10);
        q.push(SimTime::from_secs(20), 20);
        let (t, v) = q.pop().unwrap();
        assert_eq!((t, v), (SimTime::from_secs(10), 10));
        // Schedule something before the remaining event.
        q.push(SimTime::from_secs(15), 15);
        assert_eq!(q.pop().unwrap().1, 15);
        assert_eq!(q.pop().unwrap().1, 20);
    }
}
