//! The discrete-event simulation loop.
//!
//! [`Engine`] owns the virtual [`Clock`] and an [`EventQueue`]; the caller
//! provides a handler that receives each event along with `&mut Engine` so it
//! can schedule follow-up events. Time only moves forward; handlers may not
//! schedule events in the past.

use super::event::EventQueue;
use super::time::SimTime;

/// The virtual clock. Monotonically non-decreasing.
#[derive(Debug, Clone, Copy, Default)]
pub struct Clock {
    now: SimTime,
}

impl Clock {
    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }
}

/// A discrete-event engine over event payloads of type `E`.
pub struct Engine<E> {
    clock: Clock,
    queue: EventQueue<E>,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    /// New engine at t=0 with an empty queue.
    pub fn new() -> Self {
        Self {
            clock: Clock::default(),
            queue: EventQueue::new(),
            processed: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.clock.now()
    }

    /// Total events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule an event at an absolute time. Panics if `at` is in the past.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(
            at >= self.clock.now,
            "cannot schedule into the past: {:?} < {:?}",
            at,
            self.clock.now
        );
        self.queue.push(at, event);
    }

    /// Schedule an event `delay` after now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.clock.now + delay, event);
    }

    /// Run until the queue drains or `until` is reached (events at exactly
    /// `until` ARE processed). The handler gets `(&mut Engine, SimTime, E)`.
    ///
    /// Returns the number of events processed by this call.
    pub fn run_until(&mut self, until: SimTime, mut handler: impl FnMut(&mut Engine<E>, SimTime, E)) -> u64 {
        let start_count = self.processed;
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            let (t, ev) = self.queue.pop().expect("peeked");
            debug_assert!(t >= self.clock.now, "event queue went backwards");
            self.clock.now = t;
            self.processed += 1;
            handler(self, t, ev);
        }
        // Advance the clock to `until` so subsequent scheduling is relative
        // to the end of the window (but never move backwards).
        if until > self.clock.now && until != SimTime::MAX {
            self.clock.now = until;
        }
        self.processed - start_count
    }

    /// Run until the queue fully drains.
    pub fn run_to_completion(&mut self, handler: impl FnMut(&mut Engine<E>, SimTime, E)) -> u64 {
        self.run_until(SimTime::MAX, handler)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Ev {
        Ping(u32),
        Pong(u32),
    }

    #[test]
    fn processes_in_order_and_advances_clock() {
        let mut eng = Engine::new();
        eng.schedule_at(SimTime::from_secs(2), Ev::Ping(2));
        eng.schedule_at(SimTime::from_secs(1), Ev::Ping(1));
        let mut seen = Vec::new();
        eng.run_to_completion(|eng, t, ev| {
            seen.push((t, format!("{ev:?}")));
            assert_eq!(eng.now(), t);
        });
        assert_eq!(seen.len(), 2);
        assert_eq!(seen[0].0, SimTime::from_secs(1));
        assert_eq!(seen[1].0, SimTime::from_secs(2));
    }

    #[test]
    fn handler_can_schedule_followups() {
        let mut eng = Engine::new();
        eng.schedule_at(SimTime::from_secs(1), Ev::Ping(0));
        let mut pongs = 0;
        eng.run_to_completion(|eng, _, ev| match ev {
            Ev::Ping(n) if n < 3 => {
                eng.schedule_in(SimTime::from_secs(1), Ev::Ping(n + 1));
                eng.schedule_in(SimTime::from_millis(1), Ev::Pong(n));
            }
            Ev::Ping(_) => {}
            Ev::Pong(_) => pongs += 1,
        });
        assert_eq!(pongs, 3);
        assert_eq!(eng.processed(), 7); // 4 pings + 3 pongs
    }

    #[test]
    fn run_until_stops_and_clock_lands_on_boundary() {
        let mut eng = Engine::new();
        eng.schedule_at(SimTime::from_secs(1), Ev::Ping(1));
        eng.schedule_at(SimTime::from_secs(5), Ev::Ping(5));
        let n = eng.run_until(SimTime::from_secs(3), |_, _, _| {});
        assert_eq!(n, 1);
        assert_eq!(eng.now(), SimTime::from_secs(3));
        assert_eq!(eng.pending(), 1);
        // Event exactly at `until` is processed.
        let n = eng.run_until(SimTime::from_secs(5), |_, _, _| {});
        assert_eq!(n, 1);
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn scheduling_into_past_panics() {
        let mut eng = Engine::new();
        eng.schedule_at(SimTime::from_secs(10), Ev::Ping(0));
        eng.run_to_completion(|eng, _, _| {
            eng.schedule_at(SimTime::from_secs(1), Ev::Ping(1));
        });
    }
}
