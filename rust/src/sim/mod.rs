//! Discrete-event simulation core.
//!
//! The paper's results are *latency* phenomena inside a cluster scheduler.
//! Reproducing them without a 41,472-core Slurm installation requires a
//! faithful discrete-event model of the scheduler's control flow driven by a
//! calibrated cost model. This module provides the domain-agnostic pieces:
//!
//! * [`time`] — [`SimTime`]: virtual time as integer nanoseconds.
//! * [`event`] — a deterministic timed event queue (`EventQueue<E>`).
//! * [`engine`] — the DES loop ([`Engine`]) plus the virtual [`Clock`].
//! * [`costs`] — the calibrated latency constants ([`SchedCosts`]) with the
//!   rationale for each value (see also DESIGN.md §6).

pub mod costs;
pub mod engine;
pub mod event;
pub mod time;

pub use costs::SchedCosts;
pub use engine::{Clock, Engine};
pub use event::EventQueue;
pub use time::SimTime;
