//! Wire codec: renders and parses [`Request`]s and [`Response`]s for both
//! protocol versions.
//!
//! * **v1** is the original line grammar. Every request line the seed
//!   daemon accepted still parses unchanged, and the `SUBMIT` / `SQUEUE` /
//!   `SCANCEL` / `PING` response shapes are byte-compatible; the `STATS`
//!   response is now a parseable single-line `key=value` record (the seed's
//!   free-form multi-line summary had no stable grammar to preserve). v1 has
//!   grown strictly additive extensions: an optional `[count]` on `SUBMIT`,
//!   `key=value` filters on `SQUEUE`, and the `SJOB` / `WAIT` / `HELLO`
//!   verbs.
//! * **v2** is a tagged `key=value` grammar with self-describing responses
//!   (`OK kind=submit_ack first=1 last=10000 count=10000`), negotiated per
//!   connection by sending `HELLO v2`.
//!
//! Rendering and parsing are exact inverses for canonical forms:
//! `render_request(parse_request(line)) == line` and
//! `parse_response(render_response(resp)) == resp` — the round-trip tests
//! below pin both versions, including the seed grammar verbatim.

use super::api::{
    job_type_arg, parse_job_type, parse_qos, parse_state, state_token, ApiError, ContentionStats,
    ErrorCode, HealthReport, HealthState, JobDetail, JobSummary, JournalStats, ProtocolVersion,
    Request, Response, ResumeEntry, ResumeInfo,
    ResumeTarget, ShardKind, ShardStats, ShardUtil, SqueueFilter, StatsSnapshot, SubmitAck,
    SubmitSpec, UserScaleStats, UtilSnapshot, WaitResult,
};
use super::manifest::{
    EntryAck, EntryReject, Manifest, ManifestAck, ManifestChunk, ManifestEntry,
    MAX_CHUNKED_MANIFEST_ENTRIES, MAX_CHUNK_PARTS, MAX_MANIFEST_ENTRIES,
};
use crate::job::{JobState, JobType, QosClass};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Arc;

/// Cap on one serialized manifest entry record (bytes). A record is a short
/// `key=value` list with a ≤64-byte tag; anything longer is hostile input
/// and is rejected as a whole-request typed error before any admission.
pub const MAX_ENTRY_RECORD_BYTES: usize = 256;

// ---- shared token helpers --------------------------------------------------

/// Render an `f64` with Rust's shortest round-trip formatting (`600` for
/// `600.0`, `0.5` for `0.5`), so canonical lines re-parse exactly.
fn fmt_f64(x: f64) -> String {
    format!("{x}")
}

fn parse_u32(what: &str, tok: &str) -> Result<u32, ApiError> {
    tok.parse().map_err(|_| ApiError::bad_arg(what, tok))
}

fn parse_u64(what: &str, tok: &str) -> Result<u64, ApiError> {
    tok.parse().map_err(|_| ApiError::bad_arg(what, tok))
}

fn parse_usize(what: &str, tok: &str) -> Result<usize, ApiError> {
    tok.parse().map_err(|_| ApiError::bad_arg(what, tok))
}

fn parse_f64(what: &str, tok: &str) -> Result<f64, ApiError> {
    tok.parse().map_err(|_| ApiError::bad_arg(what, tok))
}

/// Split `key=value` tokens; any bare token is a `BadArg` for `what`.
fn kv_pairs<'a>(tokens: &[&'a str], what: &str) -> Result<Vec<(&'a str, &'a str)>, ApiError> {
    tokens
        .iter()
        .map(|tok| {
            tok.split_once('=')
                .ok_or_else(|| ApiError::bad_arg(what, tok))
        })
        .collect()
}

/// `key=value` tokens of one payload line → map (later keys win).
fn kv_map(line: &str) -> BTreeMap<&str, &str> {
    line.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .collect()
}

fn take<'a>(map: &BTreeMap<&'a str, &'a str>, key: &str) -> Result<&'a str, ApiError> {
    map.get(key)
        .copied()
        .ok_or_else(|| ApiError::new(ErrorCode::Internal, format!("response missing {key}=")))
}

fn take_u32(map: &BTreeMap<&str, &str>, key: &str) -> Result<u32, ApiError> {
    parse_u32(key, take(map, key)?)
}

fn take_u64(map: &BTreeMap<&str, &str>, key: &str) -> Result<u64, ApiError> {
    parse_u64(key, take(map, key)?)
}

fn take_usize(map: &BTreeMap<&str, &str>, key: &str) -> Result<usize, ApiError> {
    parse_usize(key, take(map, key)?)
}

fn take_f64(map: &BTreeMap<&str, &str>, key: &str) -> Result<f64, ApiError> {
    parse_f64(key, take(map, key)?)
}

fn take_bool(map: &BTreeMap<&str, &str>, key: &str) -> Result<bool, ApiError> {
    match take(map, key)? {
        "true" => Ok(true),
        "false" => Ok(false),
        other => Err(ApiError::bad_arg(key, other)),
    }
}

fn take_opt_f64(map: &BTreeMap<&str, &str>, key: &str) -> Result<Option<f64>, ApiError> {
    match take(map, key)? {
        "-" => Ok(None),
        tok => parse_f64(key, tok).map(Some),
    }
}

fn take_opt_u64(map: &BTreeMap<&str, &str>, key: &str) -> Result<Option<u64>, ApiError> {
    match take(map, key)? {
        "-" => Ok(None),
        tok => parse_u64(key, tok).map(Some),
    }
}

fn take_qos(map: &BTreeMap<&str, &str>, key: &str) -> Result<QosClass, ApiError> {
    let tok = take(map, key)?;
    parse_qos(tok).ok_or_else(|| ApiError::bad_arg("qos", tok))
}

fn take_job_type(map: &BTreeMap<&str, &str>, key: &str) -> Result<JobType, ApiError> {
    let tok = take(map, key)?;
    parse_job_type(tok).ok_or_else(|| ApiError::bad_arg("job type", tok))
}

fn take_state(map: &BTreeMap<&str, &str>, key: &str) -> Result<JobState, ApiError> {
    let tok = take(map, key)?;
    parse_state(tok).ok_or_else(|| ApiError::bad_arg("state", tok))
}

fn opt_f64_token(v: Option<f64>) -> String {
    v.map(fmt_f64).unwrap_or_else(|| "-".to_string())
}

fn opt_u64_token(v: Option<u64>) -> String {
    v.map(|x| x.to_string()).unwrap_or_else(|| "-".to_string())
}

// ---- request parsing -------------------------------------------------------

/// Split the optional `deadline_ms=<n>` line-prefix token off a request
/// line (v2+ only; v1 lines pass through untouched — the token was never
/// part of the v1 grammar). The deadline is a *transport*-level budget:
/// the caller stamps it against the request's arrival clock before the
/// verb even parses, so a request whose budget expired while queued is
/// dropped without ever taking a scheduler lock. It is a prefix rather
/// than a trailing key because the `MSUBMIT` body grammar owns the rest
/// of its line.
pub fn split_deadline(
    line: &str,
    version: ProtocolVersion,
) -> Result<(Option<u64>, &str), ApiError> {
    if !version.is_v2() {
        return Ok((None, line));
    }
    let trimmed = line.trim_start();
    let Some(rest) = trimmed.strip_prefix("deadline_ms=") else {
        return Ok((None, line));
    };
    let (tok, tail) = match rest.split_once(char::is_whitespace) {
        Some((tok, tail)) => (tok, tail),
        None => (rest, ""),
    };
    let ms = parse_u64("deadline_ms", tok)?;
    if ms == 0 {
        return Err(ApiError::bad_arg("deadline_ms", tok));
    }
    Ok((Some(ms), tail.trim_start()))
}

/// Parse one request line under the given protocol version.
pub fn parse_request(line: &str, version: ProtocolVersion) -> Result<Request, ApiError> {
    let tokens: Vec<&str> = line.split_whitespace().collect();
    let Some(&cmd) = tokens.first() else {
        return Err(ApiError::empty());
    };
    let rest = &tokens[1..];
    match cmd.to_ascii_uppercase().as_str() {
        "PING" => Ok(Request::Ping),
        "STATS" => Ok(Request::Stats),
        "UTIL" => Ok(Request::Util),
        // HEALTH is deliberately version-blind (like PING): an operator
        // must be able to probe a drowning daemon without negotiating.
        "HEALTH" => Ok(Request::Health),
        "SHUTDOWN" => Ok(Request::Shutdown),
        "HELLO" => {
            let tok = rest
                .first()
                .ok_or_else(|| ApiError::bad_arity("HELLO", "<v1|v2>"))?;
            let v = ProtocolVersion::parse(tok)
                .ok_or_else(|| ApiError::bad_arg("protocol version", tok))?;
            Ok(Request::Hello(v))
        }
        // The SQUEUE filter grammar is `key=value` in both versions (v1 had
        // a bare SQUEUE; filters are an additive extension).
        "SQUEUE" => parse_squeue(rest),
        "SUBMIT" => match version {
            ProtocolVersion::V1 => parse_submit_v1(rest),
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                parse_submit_v2(rest)
            }
        },
        // The manifest body is `;`-separated records, so it needs the raw
        // line, not the whitespace tokens. v1 connections get a typed
        // rejection — a single line, so nothing ever desyncs. On v2.1 the
        // header may carry `part=<i>/<k>` (a chunked stream record).
        "MSUBMIT" => match version {
            ProtocolVersion::V1 => Err(ApiError::unsupported(
                "MSUBMIT requires protocol v2 (negotiate with HELLO v2)",
            )),
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                parse_msubmit(line, version.chunked_msubmit())
            }
        },
        "SJOB" => match version {
            ProtocolVersion::V1 => {
                let tok = rest
                    .first()
                    .ok_or_else(|| ApiError::bad_arity("SJOB", "<job_id>"))?;
                Ok(Request::Sjob(parse_u64("job id", tok)?))
            }
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                let map: BTreeMap<&str, &str> = kv_pairs(rest, "SJOB option")?.into_iter().collect();
                Ok(Request::Sjob(take_u64(&map, "id")?))
            }
        },
        "SCANCEL" => match version {
            ProtocolVersion::V1 => {
                let tok = rest
                    .first()
                    .ok_or_else(|| ApiError::bad_arity("SCANCEL", "<job_id>"))?;
                Ok(Request::Scancel(parse_u64("job id", tok)?))
            }
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                let map: BTreeMap<&str, &str> =
                    kv_pairs(rest, "SCANCEL option")?.into_iter().collect();
                Ok(Request::Scancel(take_u64(&map, "id")?))
            }
        },
        "WAIT" => match version {
            ProtocolVersion::V1 => {
                if rest.len() < 2 {
                    return Err(ApiError::bad_arity("WAIT", "<job_id..> <timeout_secs>"));
                }
                let jobs = rest[..rest.len() - 1]
                    .iter()
                    .map(|tok| parse_u64("job id", tok))
                    .collect::<Result<Vec<u64>, ApiError>>()?;
                let timeout_secs = parse_f64("timeout", rest[rest.len() - 1])?;
                Ok(Request::Wait { jobs, timeout_secs })
            }
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                let map: BTreeMap<&str, &str> = kv_pairs(rest, "WAIT option")?.into_iter().collect();
                let timeout_secs = match map.get("timeout") {
                    Some(tok) => parse_f64("timeout", tok)?,
                    None => 30.0,
                };
                // The per-entry form: `WAIT manifest=<id> entry=<k>` blocks
                // on every job the manifest entry expanded to.
                if map.contains_key("manifest") {
                    if !map.contains_key("entry") {
                        return Err(ApiError::bad_arity(
                            "WAIT",
                            "manifest=<id> entry=<k> timeout=<secs>",
                        ));
                    }
                    return Ok(Request::WaitEntry {
                        manifest: take_u64(&map, "manifest")?,
                        entry: take_u32(&map, "entry")?,
                        timeout_secs,
                    });
                }
                let jobs_tok = take(&map, "jobs")
                    .map_err(|_| ApiError::bad_arity("WAIT", "jobs=<id,..> timeout=<secs>"))?;
                // An empty `jobs=` is legal: WAIT returns immediately with
                // dispatched=0 (nothing to wait for).
                let jobs = jobs_tok
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|tok| parse_u64("job id", tok))
                    .collect::<Result<Vec<u64>, ApiError>>()?;
                Ok(Request::Wait { jobs, timeout_secs })
            }
        },
        // Resume is a durability-era verb: like MSUBMIT it is v2-only, and a
        // v1 connection gets a single-line typed rejection.
        "RESUME" => match version {
            ProtocolVersion::V1 => Err(ApiError::unsupported(
                "RESUME requires protocol v2 (negotiate with HELLO v2)",
            )),
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                let map: BTreeMap<&str, &str> =
                    kv_pairs(rest, "RESUME option")?.into_iter().collect();
                match (map.get("tag"), map.get("manifest")) {
                    (Some(tag), None) => Ok(Request::Resume(ResumeTarget::Tag(tag.to_string()))),
                    (None, Some(_)) => Ok(Request::Resume(ResumeTarget::Manifest(take_u64(
                        &map, "manifest",
                    )?))),
                    _ => Err(ApiError::bad_arity(
                        "RESUME",
                        "tag=<tag> | manifest=<id> (exactly one)",
                    )),
                }
            }
        },
        _ => Err(ApiError::unknown_command(cmd)),
    }
}

fn parse_squeue(rest: &[&str]) -> Result<Request, ApiError> {
    let mut filter = SqueueFilter::default();
    for (k, v) in kv_pairs(rest, "SQUEUE filter")? {
        match k {
            "user" => filter.user = Some(parse_u32("user", v)?),
            "qos" => filter.qos = Some(parse_qos(v).ok_or_else(|| ApiError::bad_arg("qos", v))?),
            "state" => {
                filter.state = Some(parse_state(v).ok_or_else(|| ApiError::bad_arg("state", v))?)
            }
            "limit" => filter.limit = Some(parse_usize("limit", v)?),
            _ => return Err(ApiError::bad_arg("SQUEUE filter", k)),
        }
    }
    Ok(Request::Squeue(filter))
}

fn parse_submit_common(
    qos: &str,
    job_type: &str,
    tasks: &str,
    user: &str,
    run_secs: Option<&str>,
    count: Option<&str>,
) -> Result<Request, ApiError> {
    let qos = parse_qos(qos).ok_or_else(|| ApiError::bad_arg("qos", qos))?;
    let job_type = parse_job_type(job_type).ok_or_else(|| ApiError::bad_arg("job type", job_type))?;
    let tasks = parse_u32("tasks", tasks)?;
    if tasks == 0 {
        return Err(ApiError::bad_arg("tasks", "0"));
    }
    let user = parse_u32("user", user)?;
    let run_secs = match run_secs {
        Some(tok) => {
            let v = parse_f64("run_secs", tok)?;
            if !(v.is_finite() && v >= 0.0) {
                return Err(ApiError::bad_arg("run_secs", tok));
            }
            v
        }
        None => 3600.0,
    };
    let count = match count {
        Some(tok) => parse_u32("count", tok)?,
        None => 1,
    };
    if count == 0 {
        return Err(ApiError::bad_arg("count", "0"));
    }
    Ok(Request::Submit(SubmitSpec {
        qos,
        job_type,
        tasks,
        user,
        run_secs,
        count,
    }))
}

fn parse_submit_v1(rest: &[&str]) -> Result<Request, ApiError> {
    if rest.len() < 4 || rest.len() > 6 {
        return Err(ApiError::bad_arity(
            "SUBMIT",
            "<qos> <type> <tasks> <user> [run_secs] [count]",
        ));
    }
    parse_submit_common(
        rest[0],
        rest[1],
        rest[2],
        rest[3],
        rest.get(4).copied(),
        rest.get(5).copied(),
    )
}

fn parse_submit_v2(rest: &[&str]) -> Result<Request, ApiError> {
    let map: BTreeMap<&str, &str> = kv_pairs(rest, "SUBMIT option")?.into_iter().collect();
    for key in map.keys() {
        if !["qos", "type", "tasks", "user", "run_secs", "count"].contains(key) {
            return Err(ApiError::bad_arg("SUBMIT option", key));
        }
    }
    let missing = || ApiError::bad_arity("SUBMIT", "qos= type= tasks= user= [run_secs=] [count=]");
    parse_submit_common(
        map.get("qos").copied().ok_or_else(missing)?,
        map.get("type").copied().ok_or_else(missing)?,
        map.get("tasks").copied().ok_or_else(missing)?,
        map.get("user").copied().ok_or_else(missing)?,
        map.get("run_secs").copied(),
        map.get("count").copied(),
    )
}

// ---- manifest (MSUBMIT) wire body ------------------------------------------
//
// One line: `MSUBMIT entries=<n>;<record>;<record>;...` — the header's
// `entries=` count must match the record count exactly (a truncated or
// padded body is a typed whole-request error, never a desync: the line
// framing already bounds the body). Records are `key=value` tokens; tags
// are whitespace- and `;`-free by charset, so splitting is unambiguous.

/// Parse one manifest entry record (the `key=value` list between `;`
/// separators; also the line grammar of CLI manifest files). Wire-level
/// malformation — unknown/duplicate/missing keys, unparseable numbers,
/// an overlong record — is a typed error; *semantic* validation (zero
/// tasks, bad tag charset, …) happens at admission, per entry.
pub fn parse_manifest_entry(record: &str) -> Result<ManifestEntry, ApiError> {
    if record.len() > MAX_ENTRY_RECORD_BYTES {
        return Err(ApiError::bad_arg(
            "manifest entry",
            &format!("record of {} bytes (cap {MAX_ENTRY_RECORD_BYTES})", record.len()),
        ));
    }
    let tokens: Vec<&str> = record.split_whitespace().collect();
    if tokens.is_empty() {
        return Err(ApiError::bad_arg("manifest entry", "<empty record>"));
    }
    let mut map: BTreeMap<&str, &str> = BTreeMap::new();
    for (k, v) in kv_pairs(&tokens, "manifest entry")? {
        if !["qos", "type", "tasks", "user", "cores_per_task", "run_secs", "count", "tag"]
            .contains(&k)
        {
            return Err(ApiError::bad_arg("manifest entry key", k));
        }
        if map.insert(k, v).is_some() {
            return Err(ApiError::bad_arg("duplicate manifest entry key", k));
        }
    }
    let missing = || {
        ApiError::bad_arity(
            "MSUBMIT entry",
            "qos= type= tasks= user= [cores_per_task=] [run_secs=] [count=] [tag=]",
        )
    };
    let qos_tok = map.get("qos").copied().ok_or_else(missing)?;
    let type_tok = map.get("type").copied().ok_or_else(missing)?;
    let tasks_tok = map.get("tasks").copied().ok_or_else(missing)?;
    let user_tok = map.get("user").copied().ok_or_else(missing)?;
    let mut entry = ManifestEntry::new(
        parse_qos(qos_tok).ok_or_else(|| ApiError::bad_arg("qos", qos_tok))?,
        parse_job_type(type_tok).ok_or_else(|| ApiError::bad_arg("job type", type_tok))?,
        parse_u32("tasks", tasks_tok)?,
        parse_u32("user", user_tok)?,
    );
    if let Some(&tok) = map.get("cores_per_task") {
        entry.cores_per_task = parse_u32("cores_per_task", tok)?;
    }
    if let Some(&tok) = map.get("run_secs") {
        entry.run_secs = parse_f64("run_secs", tok)?;
    }
    if let Some(&tok) = map.get("count") {
        entry.count = parse_u32("count", tok)?;
    }
    if let Some(&tok) = map.get("tag") {
        entry.tag = Some(Arc::from(tok));
    }
    Ok(entry)
}

/// Render one manifest entry canonically (inverse of
/// [`parse_manifest_entry`] for valid entries).
pub fn render_manifest_entry(e: &ManifestEntry) -> String {
    let mut s = format!(
        "qos={} type={} tasks={} user={} cores_per_task={} run_secs={} count={}",
        e.qos,
        job_type_arg(e.job_type),
        e.tasks,
        e.user,
        e.cores_per_task,
        fmt_f64(e.run_secs),
        e.count,
    );
    if let Some(tag) = &e.tag {
        let _ = write!(s, " tag={tag}");
    }
    s
}

/// Parse the `part=<i>/<k>` header token of a chunked (v2.1) MSUBMIT.
fn parse_chunk_part(tok: &str) -> Result<(u32, u32), ApiError> {
    let (i, k) = tok
        .split_once('/')
        .ok_or_else(|| ApiError::bad_arg("part", tok))?;
    let part = parse_u32("part", i)?;
    let parts = parse_u32("parts", k)?;
    // Shape errors die at the codec before any per-connection stream state
    // exists; the assembler re-checks (it also sees hand-built chunks).
    if part == 0 || parts == 0 || part > parts || parts > MAX_CHUNK_PARTS {
        return Err(ApiError::bad_arg("part", tok));
    }
    Ok((part, parts))
}

fn parse_msubmit(line: &str, chunked: bool) -> Result<Request, ApiError> {
    // Strip the verb (already matched case-insensitively) from the raw line.
    let mut parts = line.trim_start().splitn(2, char::is_whitespace);
    parts.next();
    let body = parts.next().unwrap_or("").trim();
    let mut segments = body.split(';');
    let header = segments.next().unwrap_or("").trim();
    // The header segment is whitespace-separated: `entries=<n>` plus, on a
    // v2.1 chunked stream only, `part=<i>/<k>`.
    let mut head_toks = header.split_whitespace();
    let entries_tok = head_toks.next().unwrap_or("");
    let part_tok = head_toks.next();
    if head_toks.next().is_some() {
        return Err(ApiError::bad_arity(
            "MSUBMIT",
            "entries=<n>[ part=<i>/<k>];<entry>;...",
        ));
    }
    let declared = match entries_tok.strip_prefix("entries=") {
        Some(tok) => parse_usize("entries", tok)?,
        None => {
            return Err(ApiError::bad_arity(
                "MSUBMIT",
                "entries=<n>;<entry>;... (one record per declared entry)",
            ))
        }
    };
    let chunk_pos = match part_tok {
        None => None,
        Some(tok) => {
            let val = tok
                .strip_prefix("part=")
                .ok_or_else(|| ApiError::bad_arg("MSUBMIT header", tok))?;
            if !chunked {
                return Err(ApiError::unsupported(
                    "chunked MSUBMIT requires protocol v2.1 (negotiate with HELLO v2.1)",
                ));
            }
            Some(parse_chunk_part(val)?)
        }
    };
    // A chunked stream declares the whole manifest up front, so its cap is
    // the assembled-manifest cap, not the single-line cap.
    let cap = if chunk_pos.is_some() {
        MAX_CHUNKED_MANIFEST_ENTRIES
    } else {
        MAX_MANIFEST_ENTRIES
    };
    if declared > cap {
        return Err(ApiError::bad_arg(
            "entries",
            &format!("{declared} (cap {cap})"),
        ));
    }
    let mut entries = Vec::with_capacity(declared.min(4096));
    for segment in segments {
        if entries.len() >= declared {
            // More records than declared: padded/hostile body.
            return Err(ApiError::bad_arity(
                "MSUBMIT",
                &format!("entries={declared} but the body carries more records"),
            ));
        }
        entries.push(parse_manifest_entry(segment.trim())?);
    }
    if let Some((part, parts)) = chunk_pos {
        // One part carries a slice of the declared total; the assembler
        // enforces the cross-part count when the final part closes the
        // stream. The cap check above keeps `declared as u32` lossless.
        return Ok(Request::MSubmitChunk(ManifestChunk {
            entries: declared as u32,
            part,
            parts,
            records: entries,
        }));
    }
    if entries.len() != declared {
        // Fewer records than declared: truncated body.
        return Err(ApiError::bad_arity(
            "MSUBMIT",
            &format!("entries={declared} but the body carries {}", entries.len()),
        ));
    }
    Ok(Request::MSubmit(Manifest { entries }))
}

fn render_msubmit(m: &Manifest) -> String {
    let mut s = format!("MSUBMIT entries={}", m.entries.len());
    for e in &m.entries {
        s.push(';');
        s.push_str(&render_manifest_entry(e));
    }
    s
}

fn render_msubmit_chunk(c: &ManifestChunk) -> String {
    let mut s = format!("MSUBMIT entries={} part={}/{}", c.entries, c.part, c.parts);
    for e in &c.records {
        s.push(';');
        s.push_str(&render_manifest_entry(e));
    }
    s
}

// ---- request rendering -----------------------------------------------------

/// Render a request canonically for the given protocol version.
pub fn render_request(req: &Request, version: ProtocolVersion) -> String {
    match req {
        Request::Ping => "PING".into(),
        Request::Stats => "STATS".into(),
        Request::Util => "UTIL".into(),
        Request::Health => "HEALTH".into(),
        Request::Shutdown => "SHUTDOWN".into(),
        Request::Hello(v) => format!("HELLO {v}"),
        Request::Squeue(f) => {
            let mut s = String::from("SQUEUE");
            if let Some(u) = f.user {
                let _ = write!(s, " user={u}");
            }
            if let Some(q) = f.qos {
                let _ = write!(s, " qos={q}");
            }
            if let Some(st) = f.state {
                let _ = write!(s, " state={}", state_token(st));
            }
            if let Some(l) = f.limit {
                let _ = write!(s, " limit={l}");
            }
            s
        }
        Request::Sjob(id) => match version {
            ProtocolVersion::V1 => format!("SJOB {id}"),
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                format!("SJOB id={id}")
            }
        },
        Request::Scancel(id) => match version {
            ProtocolVersion::V1 => format!("SCANCEL {id}"),
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                format!("SCANCEL id={id}")
            }
        },
        Request::Wait { jobs, timeout_secs } => {
            let ids: Vec<String> = jobs.iter().map(|j| j.to_string()).collect();
            match version {
                ProtocolVersion::V1 => {
                    format!("WAIT {} {}", ids.join(" "), fmt_f64(*timeout_secs))
                }
                ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
                    format!("WAIT jobs={} timeout={}", ids.join(","), fmt_f64(*timeout_secs))
                }
            }
        }
        // Canonical in the v2 grammar; v1 cannot express a manifest (the
        // daemon answers a v1 MSUBMIT with a typed `unsupported`).
        Request::MSubmit(m) => render_msubmit(m),
        // Canonical in the v2.1 grammar; rendering is total in the other
        // versions for symmetry (a v2 daemon answers with a typed
        // `unsupported`, v1 with its MSUBMIT rejection).
        Request::MSubmitChunk(c) => render_msubmit_chunk(c),
        // v2-only verbs (like MSUBMIT, rendering is total in both versions
        // for symmetry; a v1 daemon answers with a typed `unsupported`).
        Request::WaitEntry {
            manifest,
            entry,
            timeout_secs,
        } => format!(
            "WAIT manifest={manifest} entry={entry} timeout={}",
            fmt_f64(*timeout_secs)
        ),
        Request::Resume(ResumeTarget::Tag(tag)) => format!("RESUME tag={tag}"),
        Request::Resume(ResumeTarget::Manifest(id)) => format!("RESUME manifest={id}"),
        Request::Submit(s) => match version {
            ProtocolVersion::V1 => {
                let mut line = format!(
                    "SUBMIT {} {} {} {} {}",
                    s.qos,
                    job_type_arg(s.job_type),
                    s.tasks,
                    s.user,
                    fmt_f64(s.run_secs)
                );
                if s.count != 1 {
                    let _ = write!(line, " {}", s.count);
                }
                line
            }
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => format!(
                "SUBMIT qos={} type={} tasks={} user={} run_secs={} count={}",
                s.qos,
                job_type_arg(s.job_type),
                s.tasks,
                s.user,
                fmt_f64(s.run_secs),
                s.count
            ),
        },
    }
}

// ---- response rendering ----------------------------------------------------

fn detail_kv(d: &JobDetail) -> String {
    format!(
        "id={} type={} tasks={} user={} qos={} state={} submit_secs={} queue_secs={} \
         start_secs={} end_secs={} requeues={} recognized_secs={} dispatched_secs={} \
         latency_ns={} tag={}",
        d.id,
        job_type_arg(d.job_type),
        d.tasks,
        d.user,
        d.qos,
        state_token(d.state),
        fmt_f64(d.submit_secs),
        fmt_f64(d.queue_secs),
        opt_f64_token(d.start_secs),
        opt_f64_token(d.end_secs),
        d.requeues,
        opt_f64_token(d.recognized_secs),
        opt_f64_token(d.dispatched_secs),
        opt_u64_token(d.latency_ns),
        d.tag.as_deref().unwrap_or("-"),
    )
}

fn manifest_ack_head(a: &ManifestAck) -> String {
    let mut head = format!(
        "accepted={} rejected={} jobs={}",
        a.accepted.len(),
        a.rejected.len(),
        a.jobs
    );
    // Additive extension: the daemon-assigned manifest id (for RESUME and
    // per-entry WAIT). Omitted when absent so pre-durability parsers that
    // reject unknown keys never see it.
    if let Some(id) = a.manifest {
        let _ = write!(head, " manifest={id}");
    }
    head
}

/// Append the per-entry record lines: `acc index=.. first=.. last=..
/// count=..` and `rej index=.. code=.. msg=<rest of line>`. One record per
/// line — reject messages may contain spaces (`msg=` is last and greedy)
/// but never a newline, so the framing holds.
fn render_manifest_ack_records(body: &mut String, a: &ManifestAck) {
    for acc in &a.accepted {
        let _ = write!(
            body,
            "\nacc index={} first={} last={} count={}",
            acc.index, acc.first, acc.last, acc.count
        );
    }
    for rej in &a.rejected {
        let msg: String = rej
            .error
            .message
            .chars()
            .map(|c| if c == '\n' || c == '\r' { ' ' } else { c })
            .collect();
        let _ = write!(body, "\nrej index={} code={} msg={}", rej.index, rej.error.code, msg);
    }
}

/// Parse a manifest ack body: the head `key=value`s plus `acc`/`rej`
/// record lines (shared by both protocol versions).
fn parse_manifest_ack(head: &BTreeMap<&str, &str>, tail: &str) -> Result<Response, ApiError> {
    let declared_acc = take_usize(head, "accepted")?;
    let declared_rej = take_usize(head, "rejected")?;
    let jobs = take_u64(head, "jobs")?;
    // `manifest=` is optional (absent from pre-durability servers).
    let manifest = match head.get("manifest") {
        Some(tok) => Some(parse_u64("manifest", tok)?),
        None => None,
    };
    let mut ack = ManifestAck {
        accepted: Vec::with_capacity(declared_acc.min(4096)),
        rejected: Vec::with_capacity(declared_rej.min(4096)),
        jobs,
        manifest,
    };
    let mut summed = 0u64;
    for line in tail.lines() {
        if let Some(rest) = line.strip_prefix("acc ") {
            let m = kv_map(rest);
            let acc = EntryAck {
                index: take_u32(&m, "index")?,
                first: take_u64(&m, "first")?,
                last: take_u64(&m, "last")?,
                count: take_u64(&m, "count")?,
            };
            // Range sanity before the record can reach iteration helpers
            // (EntryAck::ids / ManifestAck::job_ids): a hostile or buggy
            // peer must not be able to make the client iterate 2^64 ids.
            // Checked arithmetic: first>last and a full-u64 span both come
            // out as None rather than wrapping.
            let span = acc
                .last
                .checked_sub(acc.first)
                .and_then(|d| d.checked_add(1));
            if span != Some(acc.count) {
                return Err(ApiError::new(
                    ErrorCode::Internal,
                    format!(
                        "manifest ack record has an inconsistent id range: \
                         first={} last={} count={}",
                        acc.first, acc.last, acc.count
                    ),
                ));
            }
            summed = summed.saturating_add(acc.count);
            ack.accepted.push(acc);
        } else if let Some(rest) = line.strip_prefix("rej ") {
            let (kv, msg) = match rest.split_once(" msg=") {
                Some((kv, msg)) => (kv, msg),
                None => (rest, ""),
            };
            let m = kv_map(kv);
            let code = ErrorCode::parse(take(&m, "code")?).unwrap_or(ErrorCode::Internal);
            ack.rejected.push(EntryReject {
                index: take_u32(&m, "index")?,
                error: ApiError::new(code, msg),
            });
        }
    }
    if ack.accepted.len() != declared_acc || ack.rejected.len() != declared_rej {
        return Err(ApiError::new(
            ErrorCode::Internal,
            format!(
                "manifest ack declared {declared_acc}+{declared_rej} records, carried {}+{}",
                ack.accepted.len(),
                ack.rejected.len()
            ),
        ));
    }
    if summed != jobs {
        return Err(ApiError::new(
            ErrorCode::Internal,
            format!("manifest ack claims jobs={jobs} but its records sum to {summed}"),
        ));
    }
    Ok(Response::ManifestAck(ack))
}

/// Render the RESUME body: the head `manifest=.. entries=..` plus one
/// `ent index=.. first=.. count=.. settled=.. tag=..` record line per
/// manifest entry (shared by both protocol versions).
fn render_resume_records(body: &mut String, info: &ResumeInfo) {
    for e in &info.entries {
        let _ = write!(
            body,
            "\nent index={} first={} count={} settled={} tag={}",
            e.index,
            e.first,
            e.count,
            e.settled,
            e.tag.as_deref().unwrap_or("-")
        );
    }
}

/// Parse a RESUME body: head `key=value`s plus `ent` record lines (shared
/// by both protocol versions). Record sanity mirrors the manifest-ack
/// parser: a hostile peer must not hand the client a record whose id range
/// would iterate astronomically or whose settled count exceeds its size.
fn parse_resume(head: &BTreeMap<&str, &str>, tail: &str) -> Result<Response, ApiError> {
    let manifest = take_u64(head, "manifest")?;
    let declared = take_usize(head, "entries")?;
    let mut info = ResumeInfo {
        manifest,
        entries: Vec::with_capacity(declared.min(4096)),
    };
    for line in tail.lines() {
        let Some(rest) = line.strip_prefix("ent ") else {
            continue;
        };
        let m = kv_map(rest);
        let ent = ResumeEntry {
            index: take_u32(&m, "index")?,
            first: take_u64(&m, "first")?,
            count: take_u64(&m, "count")?,
            settled: take_u64(&m, "settled")?,
            tag: take_opt_tag(&m),
        };
        if ent.first.checked_add(ent.count).is_none() || ent.settled > ent.count {
            return Err(ApiError::new(
                ErrorCode::Internal,
                format!(
                    "resume record has an inconsistent id range: first={} count={} settled={}",
                    ent.first, ent.count, ent.settled
                ),
            ));
        }
        info.entries.push(ent);
    }
    if info.entries.len() != declared {
        return Err(ApiError::new(
            ErrorCode::Internal,
            format!(
                "resume body declared {declared} entries, carried {}",
                info.entries.len()
            ),
        ));
    }
    Ok(Response::Resume(info))
}

fn wait_kv(w: &WaitResult) -> String {
    format!(
        "requested={} dispatched={} timed_out={} latency_ns={}",
        w.requested, w.dispatched, w.timed_out, w.latency_ns
    )
}

/// Render the HEALTH body (shared by both protocol versions — the verb is
/// version-blind, like PING).
fn health_kv(h: &HealthReport) -> String {
    format!(
        "state={} since_secs={} inflight={} inflight_budget={} shed_submits={} shed_msubmits={} \
         rate_limited={} deadline_expired={} conns_evicted={} journal_poisoned={}",
        h.state.as_str(),
        fmt_f64(h.since_secs),
        h.inflight,
        h.inflight_budget,
        h.shed_submits,
        h.shed_msubmits,
        h.rate_limited,
        h.deadline_expired,
        h.conns_evicted,
        h.journal_poisoned,
    )
}

fn parse_health(map: &BTreeMap<&str, &str>) -> Result<HealthReport, ApiError> {
    let tok = take(map, "state")?;
    Ok(HealthReport {
        state: HealthState::parse(tok).ok_or_else(|| ApiError::bad_arg("health state", tok))?,
        since_secs: take_f64(map, "since_secs")?,
        inflight: take_u64(map, "inflight")?,
        inflight_budget: take_u64(map, "inflight_budget")?,
        shed_submits: take_u64(map, "shed_submits")?,
        shed_msubmits: take_u64(map, "shed_msubmits")?,
        rate_limited: take_u64(map, "rate_limited")?,
        deadline_expired: take_u64(map, "deadline_expired")?,
        conns_evicted: take_u64(map, "conns_evicted")?,
        journal_poisoned: take_u64(map, "journal_poisoned")?,
    })
}

/// Render the STATS body. `with_contention` appends the v2-only contention
/// extension keys (v1 keeps the original key set byte-compatible; v2
/// parsers treat the keys as optional, so mixed versions interoperate).
fn stats_kv(s: &StatsSnapshot, with_contention: bool) -> String {
    let mut out = format!(
        "virtual_now_secs={} dispatches={} preemptions={} requeues={} cron_passes={} \
         main_passes={} backfill_passes={} triggered_passes={} score_batches={} jobs_scored={} \
         scorer={} requests_ok={} requests_err={} jobs_submitted={} sched_latency_count={} \
         sched_latency_p50_ns={}",
        fmt_f64(s.virtual_now_secs),
        s.dispatches,
        s.preemptions,
        s.requeues,
        s.cron_passes,
        s.main_passes,
        s.backfill_passes,
        s.triggered_passes,
        s.score_batches,
        s.jobs_scored,
        s.scorer,
        s.requests_ok,
        s.requests_err,
        s.jobs_submitted,
        s.sched_latency_count,
        s.sched_latency_p50_ns,
    );
    if with_contention {
        if let Some(c) = &s.contention {
            let _ = write!(
                out,
                " read_path_ops={} write_locks={} waits_parked={} waits_resumed={} \
                 lock_hold_count={} lock_hold_p50_ns={} lock_hold_p99_ns={} lock_hold_max_ns={}",
                c.read_path_ops,
                c.write_locks,
                c.waits_parked,
                c.waits_resumed,
                c.lock_hold_count,
                c.lock_hold_p50_ns,
                c.lock_hold_p99_ns,
                c.lock_hold_max_ns,
            );
        }
        // Journal keys ride the same v2-only extension train: present only
        // when the daemon journals, optional to parsers either way.
        if let Some(j) = &s.journal {
            let _ = write!(
                out,
                " journal_appends={} journal_synced_appends={} journal_group_commits={} \
                 journal_poisoned={}",
                j.appends, j.synced_appends, j.group_commits, j.poisoned,
            );
        }
        // Overload-control-plane keys: same additive pattern, keyed on
        // `health_state` as a block. The health-namespaced spelling keeps
        // `journal_poisoned` (the journal block's key) collision-free.
        if let Some(h) = &s.health {
            let _ = write!(
                out,
                " health_state={} health_since_secs={} health_inflight={} \
                 health_inflight_budget={} shed_submits={} shed_msubmits={} \
                 shed_rate_limited={} shed_deadline_expired={} shed_conns_evicted={} \
                 health_journal_poisoned={}",
                h.state.as_str(),
                fmt_f64(h.since_secs),
                h.inflight,
                h.inflight_budget,
                h.shed_submits,
                h.shed_msubmits,
                h.rate_limited,
                h.deadline_expired,
                h.conns_evicted,
                h.journal_poisoned,
            );
        }
        // User-cardinality gauges: same additive v2-only pattern, keyed on
        // `users_active` as a block.
        if let Some(u) = &s.users {
            let _ = write!(
                out,
                " users_active={} users_tracked={} buckets_live={}",
                u.users_active, u.users_tracked, u.buckets_live,
            );
        }
    }
    for (cmd, n) in &s.commands {
        let _ = write!(out, " cmd_{cmd}={n}");
    }
    out
}

/// Append the per-shard STATS records, one line per shard: `shard kind=..
/// index=.. label=.. wakeups=.. events=.. connections=.. parked=..
/// queue_depth=.. lock_hold_p99_ns=..`. An additive v2 extension: v1 keeps
/// its key set byte-compatible (no shard lines), and v2 parsers accept
/// their absence, so mixed versions interoperate.
fn render_shard_stats_records(body: &mut String, shards: &[ShardStats]) {
    for sh in shards {
        let _ = write!(
            body,
            "\nshard kind={} index={} label={} wakeups={} events={} connections={} parked={} \
             queue_depth={} lock_hold_p99_ns={}",
            sh.kind.as_str(),
            sh.index,
            sh.label,
            sh.wakeups,
            sh.events,
            sh.connections,
            sh.parked,
            sh.queue_depth,
            sh.lock_hold_p99_ns,
        );
    }
}

/// Render a response for the given protocol version. The result is the body
/// only — the transport appends the blank-line terminator. (v2.1 responses
/// render exactly as v2; the version only gates the chunked request body.)
pub fn render_response(resp: &Response, version: ProtocolVersion) -> String {
    match version {
        ProtocolVersion::V1 => render_response_v1(resp),
        ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
            render_response_v2(resp)
        }
    }
}

fn render_response_v1(resp: &Response) -> String {
    match resp {
        Response::Pong => "OK pong".into(),
        Response::Hello(v) => format!("OK proto={v}"),
        Response::ShuttingDown => "OK shutting down".into(),
        Response::SubmitAck(a) => format!("OK jobs={}-{} count={}", a.first, a.last, a.count),
        Response::ManifestAck(a) => {
            // Not byte-constrained: MSUBMIT itself is v2-only, but rendering
            // must be total (and round-trips, for symmetry with v2).
            let mut body = format!("OK manifest {}", manifest_ack_head(a));
            render_manifest_ack_records(&mut body, a);
            body
        }
        Response::ChunkAck {
            part,
            parts,
            received,
        } => {
            // Not byte-constrained: chunked MSUBMIT is v2.1-only, but
            // rendering must be total (and round-trips, for symmetry).
            format!("OK chunk_ack part={part} parts={parts} received={received}")
        }
        Response::Cancelled(id) => format!("OK cancelled {id}"),
        Response::Jobs(rows) => {
            // Byte-compatible with the seed SQUEUE table.
            let mut body = String::from("OK \nJOBID TYPE TASKS USER QOS STATE\n");
            for r in rows {
                let _ = writeln!(
                    body,
                    "{} {} {} user{} {} {:?}",
                    r.id,
                    r.job_type.label(),
                    r.tasks,
                    r.user,
                    r.qos,
                    r.state
                );
            }
            let _ = write!(body, "({} jobs)", rows.len());
            body
        }
        Response::Job(d) => format!("OK {}", detail_kv(d)),
        Response::Wait(w) => format!("OK {}", wait_kv(w)),
        Response::Resume(info) => {
            // Not byte-constrained: RESUME itself is v2-only, but rendering
            // must be total (and round-trips, for symmetry with v2).
            let mut body = format!(
                "OK resume manifest={} entries={}",
                info.manifest,
                info.entries.len()
            );
            render_resume_records(&mut body, info);
            body
        }
        Response::Stats(s) => format!("OK {}", stats_kv(s, false)),
        Response::Util(u) => format!(
            "OK utilization={:.4} idle_cores={} idle_nodes={} total_cores={} pending={} running={}",
            u.utilization, u.idle_cores, u.idle_nodes, u.total_cores, u.pending, u.running
        ),
        // Not byte-constrained: HEALTH is a new verb, so v1 renders the
        // same record behind a `health` discriminator token.
        Response::Health(h) => format!("OK health {}", health_kv(h)),
        // The v1 grammar predates retry hints; the hint is dropped (a v1
        // client backs off on its own schedule).
        Response::Error(e) => format!("ERR {}: {}", e.code, e.message),
    }
}

fn render_response_v2(resp: &Response) -> String {
    match resp {
        Response::Pong => "OK kind=pong".into(),
        Response::Hello(v) => format!("OK kind=hello proto={v}"),
        Response::ShuttingDown => "OK kind=shutdown".into(),
        Response::SubmitAck(a) => format!(
            "OK kind=submit_ack first={} last={} count={}",
            a.first, a.last, a.count
        ),
        Response::Cancelled(id) => format!("OK kind=cancelled id={id}"),
        Response::Jobs(rows) => {
            let mut body = format!("OK kind=jobs count={}", rows.len());
            for r in rows {
                let _ = write!(
                    body,
                    "\njob id={} type={} tasks={} user={} qos={} state={}",
                    r.id,
                    job_type_arg(r.job_type),
                    r.tasks,
                    r.user,
                    r.qos,
                    state_token(r.state)
                );
                if let Some(tag) = &r.tag {
                    let _ = write!(body, " tag={tag}");
                }
            }
            body
        }
        Response::ManifestAck(a) => {
            let mut body = format!("OK kind=manifest_ack {}", manifest_ack_head(a));
            render_manifest_ack_records(&mut body, a);
            body
        }
        Response::ChunkAck {
            part,
            parts,
            received,
        } => format!("OK kind=chunk_ack part={part} parts={parts} received={received}"),
        Response::Job(d) => format!("OK kind=job {}", detail_kv(d)),
        Response::Wait(w) => format!("OK kind=wait {}", wait_kv(w)),
        Response::Resume(info) => {
            let mut body = format!(
                "OK kind=resume manifest={} entries={}",
                info.manifest,
                info.entries.len()
            );
            render_resume_records(&mut body, info);
            body
        }
        Response::Stats(s) => {
            let mut body = format!("OK kind=stats {}", stats_kv(s, true));
            render_shard_stats_records(&mut body, &s.shards);
            body
        }
        Response::Util(u) => {
            let mut body = format!(
                "OK kind=util utilization={} idle_cores={} idle_nodes={} total_cores={} pending={} running={}",
                fmt_f64(u.utilization), u.idle_cores, u.idle_nodes, u.total_cores, u.pending, u.running
            );
            for sh in &u.shards {
                let _ = write!(
                    body,
                    "\nshard index={} label={} utilization={} idle_cores={} total_cores={} \
                     pending={} running={}",
                    sh.index,
                    sh.label,
                    fmt_f64(sh.utilization),
                    sh.idle_cores,
                    sh.total_cores,
                    sh.pending,
                    sh.running
                );
            }
            body
        }
        Response::Health(h) => format!("OK kind=health {}", health_kv(h)),
        Response::Error(e) => {
            // `retry_after_ms=` renders BEFORE `msg=`: the message is the
            // greedy last field, so every machine key must precede it.
            let mut body = format!("ERR code={}", e.code);
            if let Some(ms) = e.retry_after_ms {
                let _ = write!(body, " retry_after_ms={ms}");
            }
            let _ = write!(body, " msg={}", e.message);
            body
        }
    }
}

// ---- response parsing ------------------------------------------------------

/// Parse a response body (as returned by the transport, terminator already
/// stripped) for the given protocol version.
pub fn parse_response(text: &str, version: ProtocolVersion) -> Result<Response, ApiError> {
    if let Some(rest) = text.strip_prefix("ERR") {
        return Ok(Response::Error(parse_error_body(rest.trim_start(), version)));
    }
    let Some(rest) = text.strip_prefix("OK") else {
        return Err(ApiError::new(
            ErrorCode::Internal,
            format!("response is neither OK nor ERR: {text:?}"),
        ));
    };
    let rest = rest.strip_prefix(' ').unwrap_or(rest);
    match version {
        ProtocolVersion::V1 => parse_ok_v1(rest),
        ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => parse_ok_v2(rest),
    }
}

fn parse_error_body(body: &str, version: ProtocolVersion) -> ApiError {
    match version {
        ProtocolVersion::V1 => match body.split_once(": ") {
            Some((code_tok, msg)) => match ErrorCode::parse(code_tok) {
                Some(code) => ApiError::new(code, msg),
                None => ApiError::new(ErrorCode::Internal, body),
            },
            None => ApiError::new(ErrorCode::Internal, body),
        },
        ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3 => {
            let (head, msg) = match body.split_once(" msg=") {
                Some((head, msg)) => (head, msg),
                None => (body, ""),
            };
            let map = kv_map(head);
            let code = map
                .get("code")
                .and_then(|c| ErrorCode::parse(c))
                .unwrap_or(ErrorCode::Internal);
            let mut err = ApiError::new(code, msg);
            // Optional backoff hint (absent from pre-overload servers; a
            // malformed value parses as absent rather than failing the
            // whole error body).
            err.retry_after_ms = map
                .get("retry_after_ms")
                .and_then(|tok| tok.parse().ok());
            err
        }
    }
}

fn parse_jobs_row_v1(line: &str) -> Result<JobSummary, ApiError> {
    let bad = || ApiError::new(ErrorCode::Internal, format!("bad SQUEUE row: {line:?}"));
    let tok: Vec<&str> = line.split_whitespace().collect();
    if tok.len() != 6 {
        return Err(bad());
    }
    Ok(JobSummary {
        id: tok[0].parse().map_err(|_| bad())?,
        job_type: parse_job_type(tok[1]).ok_or_else(bad)?,
        tasks: tok[2].parse().map_err(|_| bad())?,
        user: tok[3]
            .strip_prefix("user")
            .and_then(|u| u.parse().ok())
            .ok_or_else(bad)?,
        qos: parse_qos(tok[4]).ok_or_else(bad)?,
        state: parse_state(tok[5]).ok_or_else(bad)?,
        // The seed table cannot carry a tag (byte compatibility).
        tag: None,
    })
}

/// Optional tag token: absent or `-` parses as `None` (responses from a
/// pre-tag server still parse).
fn take_opt_tag(map: &BTreeMap<&str, &str>) -> Option<Arc<str>> {
    match map.get("tag") {
        None | Some(&"-") => None,
        Some(&t) => Some(Arc::from(t)),
    }
}

fn parse_detail(map: &BTreeMap<&str, &str>) -> Result<JobDetail, ApiError> {
    Ok(JobDetail {
        id: take_u64(map, "id")?,
        job_type: take_job_type(map, "type")?,
        tasks: take_u32(map, "tasks")?,
        user: take_u32(map, "user")?,
        qos: take_qos(map, "qos")?,
        state: take_state(map, "state")?,
        submit_secs: take_f64(map, "submit_secs")?,
        queue_secs: take_f64(map, "queue_secs")?,
        start_secs: take_opt_f64(map, "start_secs")?,
        end_secs: take_opt_f64(map, "end_secs")?,
        requeues: take_u32(map, "requeues")?,
        recognized_secs: take_opt_f64(map, "recognized_secs")?,
        dispatched_secs: take_opt_f64(map, "dispatched_secs")?,
        latency_ns: take_opt_u64(map, "latency_ns")?,
        tag: take_opt_tag(map),
    })
}

fn parse_wait(map: &BTreeMap<&str, &str>) -> Result<WaitResult, ApiError> {
    Ok(WaitResult {
        requested: take_u32(map, "requested")?,
        dispatched: take_u32(map, "dispatched")?,
        timed_out: take_bool(map, "timed_out")?,
        latency_ns: take_u64(map, "latency_ns")?,
    })
}

/// Parse the `shard ...` continuation records of a STATS body. Absent
/// lines (a v1 body, or a pre-sharding v2 server) yield an empty vec.
fn parse_shard_stats(tail: &str) -> Result<Vec<ShardStats>, ApiError> {
    let mut shards = Vec::new();
    for line in tail.lines() {
        let Some(rest) = line.strip_prefix("shard ") else {
            continue;
        };
        let m = kv_map(rest);
        let kind_tok = take(&m, "kind")?;
        shards.push(ShardStats {
            kind: ShardKind::parse(kind_tok)
                .ok_or_else(|| ApiError::bad_arg("shard kind", kind_tok))?,
            index: take_u32(&m, "index")?,
            label: take(&m, "label")?.to_string(),
            wakeups: take_u64(&m, "wakeups")?,
            events: take_u64(&m, "events")?,
            connections: take_u64(&m, "connections")?,
            parked: take_u64(&m, "parked")?,
            queue_depth: take_u64(&m, "queue_depth")?,
            lock_hold_p99_ns: take_u64(&m, "lock_hold_p99_ns")?,
        });
    }
    Ok(shards)
}

fn parse_stats(map: &BTreeMap<&str, &str>, tail: &str) -> Result<StatsSnapshot, ApiError> {
    let mut commands = BTreeMap::new();
    for (k, v) in map {
        if let Some(cmd) = k.strip_prefix("cmd_") {
            commands.insert(cmd.to_string(), parse_u64(k, v)?);
        }
    }
    // Contention keys are a v2 extension: optional as a block (keyed on the
    // first field) so responses from pre-extension servers still parse.
    let contention = if map.contains_key("read_path_ops") {
        Some(ContentionStats {
            read_path_ops: take_u64(map, "read_path_ops")?,
            write_locks: take_u64(map, "write_locks")?,
            waits_parked: take_u64(map, "waits_parked")?,
            waits_resumed: take_u64(map, "waits_resumed")?,
            lock_hold_count: take_u64(map, "lock_hold_count")?,
            lock_hold_p50_ns: take_u64(map, "lock_hold_p50_ns")?,
            lock_hold_p99_ns: take_u64(map, "lock_hold_p99_ns")?,
            lock_hold_max_ns: take_u64(map, "lock_hold_max_ns")?,
        })
    } else {
        None
    };
    // Journal keys are likewise optional as a block (keyed on
    // `journal_appends`): journal-off daemons and pre-durability servers
    // simply omit them.
    let journal = if map.contains_key("journal_appends") {
        Some(JournalStats {
            appends: take_u64(map, "journal_appends")?,
            synced_appends: take_u64(map, "journal_synced_appends")?,
            group_commits: take_u64(map, "journal_group_commits")?,
            poisoned: take_u64(map, "journal_poisoned")?,
        })
    } else {
        None
    };
    // Health keys are the overload plane's block (keyed on `health_state`):
    // absent from v1 bodies and pre-overload servers.
    let health = if map.contains_key("health_state") {
        let tok = take(map, "health_state")?;
        Some(HealthReport {
            state: HealthState::parse(tok)
                .ok_or_else(|| ApiError::bad_arg("health state", tok))?,
            since_secs: take_f64(map, "health_since_secs")?,
            inflight: take_u64(map, "health_inflight")?,
            inflight_budget: take_u64(map, "health_inflight_budget")?,
            shed_submits: take_u64(map, "shed_submits")?,
            shed_msubmits: take_u64(map, "shed_msubmits")?,
            rate_limited: take_u64(map, "shed_rate_limited")?,
            deadline_expired: take_u64(map, "shed_deadline_expired")?,
            conns_evicted: take_u64(map, "shed_conns_evicted")?,
            journal_poisoned: take_u64(map, "health_journal_poisoned")?,
        })
    } else {
        None
    };
    // User-cardinality gauges (keyed on `users_active`): absent from v1
    // bodies and pre-extension servers.
    let users = if map.contains_key("users_active") {
        Some(UserScaleStats {
            users_active: take_u64(map, "users_active")?,
            users_tracked: take_u64(map, "users_tracked")?,
            buckets_live: take_u64(map, "buckets_live")?,
        })
    } else {
        None
    };
    Ok(StatsSnapshot {
        virtual_now_secs: take_f64(map, "virtual_now_secs")?,
        dispatches: take_u64(map, "dispatches")?,
        preemptions: take_u64(map, "preemptions")?,
        requeues: take_u64(map, "requeues")?,
        cron_passes: take_u64(map, "cron_passes")?,
        main_passes: take_u64(map, "main_passes")?,
        backfill_passes: take_u64(map, "backfill_passes")?,
        triggered_passes: take_u64(map, "triggered_passes")?,
        score_batches: take_u64(map, "score_batches")?,
        jobs_scored: take_u64(map, "jobs_scored")?,
        scorer: take(map, "scorer")?.to_string(),
        requests_ok: take_u64(map, "requests_ok")?,
        requests_err: take_u64(map, "requests_err")?,
        jobs_submitted: take_u64(map, "jobs_submitted")?,
        sched_latency_count: take_u64(map, "sched_latency_count")?,
        sched_latency_p50_ns: take_u64(map, "sched_latency_p50_ns")?,
        commands,
        contention,
        shards: parse_shard_stats(tail)?,
        journal,
        health,
        users,
    })
}

fn parse_util(map: &BTreeMap<&str, &str>, tail: &str) -> Result<UtilSnapshot, ApiError> {
    let mut shards = Vec::new();
    for line in tail.lines() {
        let Some(rest) = line.strip_prefix("shard ") else {
            continue;
        };
        let m = kv_map(rest);
        shards.push(ShardUtil {
            index: take_u32(&m, "index")?,
            label: take(&m, "label")?.to_string(),
            utilization: take_f64(&m, "utilization")?,
            idle_cores: take_u32(&m, "idle_cores")?,
            total_cores: take_u32(&m, "total_cores")?,
            pending: take_usize(&m, "pending")?,
            running: take_usize(&m, "running")?,
        });
    }
    Ok(UtilSnapshot {
        utilization: take_f64(map, "utilization")?,
        idle_cores: take_u32(map, "idle_cores")?,
        idle_nodes: take_u32(map, "idle_nodes")?,
        total_cores: take_u32(map, "total_cores")?,
        pending: take_usize(map, "pending")?,
        running: take_usize(map, "running")?,
        shards,
    })
}

fn parse_submit_ack_v1(line: &str) -> Result<Response, ApiError> {
    // "jobs=<first>-<last> count=<n>"
    let map = kv_map(line);
    let range = take(&map, "jobs")?;
    let (first, last) = range
        .split_once('-')
        .ok_or_else(|| ApiError::new(ErrorCode::Internal, format!("bad id range {range:?}")))?;
    Ok(Response::SubmitAck(SubmitAck {
        first: parse_u64("first", first)?,
        last: parse_u64("last", last)?,
        count: take_u64(&map, "count")?,
    }))
}

fn parse_ok_v1(rest: &str) -> Result<Response, ApiError> {
    if rest.starts_with('\n') {
        // The SQUEUE table: header, rows, "(N jobs)".
        let lines: Vec<&str> = rest.trim_start_matches('\n').lines().collect();
        let mut rows = Vec::new();
        for line in lines.iter().skip(1) {
            if line.starts_with('(') {
                break;
            }
            rows.push(parse_jobs_row_v1(line)?);
        }
        return Ok(Response::Jobs(rows));
    }
    let first = rest.split_whitespace().next().unwrap_or("");
    match first {
        "pong" => Ok(Response::Pong),
        "shutting" => Ok(Response::ShuttingDown),
        "health" => Ok(Response::Health(parse_health(&kv_map(rest))?)),
        "cancelled" => {
            let tok = rest.split_whitespace().nth(1).unwrap_or("");
            Ok(Response::Cancelled(parse_u64("job id", tok)?))
        }
        "chunk_ack" => {
            let map = kv_map(rest);
            Ok(Response::ChunkAck {
                part: take_u32(&map, "part")?,
                parts: take_u32(&map, "parts")?,
                received: take_u64(&map, "received")?,
            })
        }
        "manifest" => {
            let (head, tail) = match rest.split_once('\n') {
                Some((h, t)) => (h, t),
                None => (rest, ""),
            };
            parse_manifest_ack(&kv_map(head), tail)
        }
        "resume" => {
            let (head, tail) = match rest.split_once('\n') {
                Some((h, t)) => (h, t),
                None => (rest, ""),
            };
            parse_resume(&kv_map(head), tail)
        }
        _ if first.starts_with("proto=") => {
            let v = first.trim_start_matches("proto=");
            ProtocolVersion::parse(v)
                .map(Response::Hello)
                .ok_or_else(|| ApiError::bad_arg("protocol version", v))
        }
        _ if first.starts_with("jobs=") => parse_submit_ack_v1(rest),
        _ if first.starts_with("virtual_now_secs=") => {
            // v1 STATS is single-line (no shard records).
            Ok(Response::Stats(parse_stats(&kv_map(rest), "")?))
        }
        _ if first.starts_with("utilization=") => {
            Ok(Response::Util(parse_util(&kv_map(rest), "")?))
        }
        _ if first.starts_with("requested=") => Ok(Response::Wait(parse_wait(&kv_map(rest))?)),
        _ if first.starts_with("id=") => Ok(Response::Job(parse_detail(&kv_map(rest))?)),
        _ => Err(ApiError::new(
            ErrorCode::Internal,
            format!("unrecognized v1 response: {rest:?}"),
        )),
    }
}

fn parse_ok_v2(rest: &str) -> Result<Response, ApiError> {
    let (head, tail) = match rest.split_once('\n') {
        Some((h, t)) => (h, t),
        None => (rest, ""),
    };
    let map = kv_map(head);
    match take(&map, "kind")? {
        "pong" => Ok(Response::Pong),
        "shutdown" => Ok(Response::ShuttingDown),
        "hello" => {
            let v = take(&map, "proto")?;
            ProtocolVersion::parse(v)
                .map(Response::Hello)
                .ok_or_else(|| ApiError::bad_arg("protocol version", v))
        }
        "submit_ack" => Ok(Response::SubmitAck(SubmitAck {
            first: take_u64(&map, "first")?,
            last: take_u64(&map, "last")?,
            count: take_u64(&map, "count")?,
        })),
        "manifest_ack" => parse_manifest_ack(&map, tail),
        "chunk_ack" => Ok(Response::ChunkAck {
            part: take_u32(&map, "part")?,
            parts: take_u32(&map, "parts")?,
            received: take_u64(&map, "received")?,
        }),
        "resume" => parse_resume(&map, tail),
        "cancelled" => Ok(Response::Cancelled(take_u64(&map, "id")?)),
        "job" => Ok(Response::Job(parse_detail(&map)?)),
        "wait" => Ok(Response::Wait(parse_wait(&map)?)),
        "stats" => Ok(Response::Stats(parse_stats(&map, tail)?)),
        "util" => Ok(Response::Util(parse_util(&map, tail)?)),
        "health" => Ok(Response::Health(parse_health(&map)?)),
        "jobs" => {
            let mut rows = Vec::new();
            for line in tail.lines() {
                let Some(body) = line.strip_prefix("job ") else {
                    continue;
                };
                let m = kv_map(body);
                rows.push(JobSummary {
                    id: take_u64(&m, "id")?,
                    job_type: take_job_type(&m, "type")?,
                    tasks: take_u32(&m, "tasks")?,
                    user: take_u32(&m, "user")?,
                    qos: take_qos(&m, "qos")?,
                    state: take_state(&m, "state")?,
                    tag: take_opt_tag(&m),
                });
            }
            Ok(Response::Jobs(rows))
        }
        other => Err(ApiError::new(
            ErrorCode::Internal,
            format!("unrecognized v2 response kind {other:?}"),
        )),
    }
}

// ---- v3 binary framing ------------------------------------------------------
//
// After the text `HELLO v3` acknowledgement the connection switches to
// length-prefixed binary frames:
//
//     frame = len:u32le  opcode:u8  payload:[u8; len-1]
//
// `len` counts the opcode byte plus the payload, so an empty payload frames
// as `len=1`. Every verb except `MSUBMIT` rides in `OP_TEXT_REQ` frames
// carrying exactly one v2.1-grammar request line (including the optional
// `deadline_ms=` prefix); responses come back in `OP_TEXT_RESP` frames
// carrying the v2-rendered body with no trailing blank line — the frame is
// the delimiter. `MSUBMIT` alone gets a packed binary encoding
// (`OP_MSUBMIT` / `OP_MANIFEST_ACK`): it is the only verb whose body scales
// with entry count, and its text parse dominated the v2 wire path. See
// `PROTOCOL.md` §v3 for the normative grammar.

/// v3 opcode: a UTF-8 request line in the v2.1 text grammar.
pub const OP_TEXT_REQ: u8 = 0x01;
/// v3 opcode: a packed binary `MSUBMIT` manifest.
pub const OP_MSUBMIT: u8 = 0x02;
/// v3 opcode: a v2-rendered response body.
pub const OP_TEXT_RESP: u8 = 0x81;
/// v3 opcode: a packed binary manifest ack.
pub const OP_MANIFEST_ACK: u8 = 0x82;

/// Cap on one v3 frame body (`len` field), matching the reactor's
/// per-connection buffered-request cap: a protocol-legal frame always gets
/// a typed response, never a buffer-overflow connection close. A peer that
/// declares a longer frame is desynchronized beyond recovery — the server
/// answers with one typed error and closes.
pub const MAX_FRAME_BYTES: usize = 4 << 20;

/// Bytes in the v3 frame header (the little-endian `len` prefix).
pub const FRAME_HEADER_BYTES: usize = 4;

/// Decode a v3 frame header from the front of `buf`. `Ok(None)` means more
/// bytes are needed; `Ok(Some(len))` means the frame body (opcode +
/// payload) is `len` bytes starting at [`FRAME_HEADER_BYTES`]; `Err` means
/// the peer declared an illegal length (zero or over [`MAX_FRAME_BYTES`]).
pub fn decode_frame_header(buf: &[u8]) -> Result<Option<usize>, ApiError> {
    if buf.len() < FRAME_HEADER_BYTES {
        return Ok(None);
    }
    let mut le = [0u8; 4];
    le.copy_from_slice(&buf[..FRAME_HEADER_BYTES]);
    let len = u32::from_le_bytes(le) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(ApiError::new(
            ErrorCode::BadArity,
            format!("v3 frame length {len} outside 1..={MAX_FRAME_BYTES}"),
        ));
    }
    Ok(Some(len))
}

/// Frame one v3 opcode + payload: `[len:u32le][opcode][payload]`.
pub fn v3_frame(opcode: u8, payload: &[u8]) -> Vec<u8> {
    let len = payload.len() + 1;
    debug_assert!(len <= MAX_FRAME_BYTES, "frame body over MAX_FRAME_BYTES");
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + len);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    out.push(opcode);
    out.extend_from_slice(payload);
    out
}

/// Append an unsigned LEB128 varint (7 value bits per byte, low group
/// first, high bit = continuation).
pub fn write_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Bounds-checked cursor over one v3 payload: truncation and overlong
/// varints come back as typed errors, never a panic or a wrap.
struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn truncated(what: &str) -> ApiError {
        ApiError::new(
            ErrorCode::BadArity,
            format!("binary payload truncated in {what}"),
        )
    }

    fn u8(&mut self, what: &str) -> Result<u8, ApiError> {
        let b = *self.buf.get(self.pos).ok_or_else(|| Self::truncated(what))?;
        self.pos += 1;
        Ok(b)
    }

    fn bytes(&mut self, len: usize, what: &str) -> Result<&'a [u8], ApiError> {
        if self.remaining() < len {
            return Err(Self::truncated(what));
        }
        let out = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(out)
    }

    /// Unsigned LEB128, at most 10 bytes; a value over `u64::MAX` (or a
    /// tenth byte above 1) is a typed `BadArg`, not silent wraparound.
    fn uvarint(&mut self, what: &str) -> Result<u64, ApiError> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8(what)?;
            if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
                return Err(ApiError::bad_arg(what, "varint overflows u64"));
            }
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn uvarint_u32(&mut self, what: &str) -> Result<u32, ApiError> {
        let v = self.uvarint(what)?;
        u32::try_from(v).map_err(|_| ApiError::bad_arg(what, &v.to_string()))
    }

    fn f64(&mut self, what: &str) -> Result<f64, ApiError> {
        let mut le = [0u8; 8];
        le.copy_from_slice(self.bytes(8, what)?);
        Ok(f64::from_le_bytes(le))
    }

    /// The payload must be fully consumed — trailing bytes mean the peer
    /// and codec disagree about the record grammar (desync risk).
    fn done(&self, what: &str) -> Result<(), ApiError> {
        if self.pos != self.buf.len() {
            return Err(ApiError::new(
                ErrorCode::BadArity,
                format!("{} trailing bytes after {what}", self.remaining()),
            ));
        }
        Ok(())
    }
}

fn qos_byte(q: QosClass) -> u8 {
    match q {
        QosClass::Normal => 0,
        QosClass::Spot => 1,
    }
}

fn qos_from_byte(b: u8) -> Result<QosClass, ApiError> {
    match b {
        0 => Ok(QosClass::Normal),
        1 => Ok(QosClass::Spot),
        other => Err(ApiError::bad_arg("qos", &other.to_string())),
    }
}

fn job_type_byte(t: JobType) -> u8 {
    match t {
        JobType::Individual => 0,
        JobType::Array => 1,
        JobType::TripleMode => 2,
    }
}

fn job_type_from_byte(b: u8) -> Result<JobType, ApiError> {
    match b {
        0 => Ok(JobType::Individual),
        1 => Ok(JobType::Array),
        2 => Ok(JobType::TripleMode),
        other => Err(ApiError::bad_arg("type", &other.to_string())),
    }
}

fn error_code_byte(c: ErrorCode) -> u8 {
    match c {
        ErrorCode::Empty => 0,
        ErrorCode::UnknownCommand => 1,
        ErrorCode::BadArity => 2,
        ErrorCode::BadArg => 3,
        ErrorCode::NotFound => 4,
        ErrorCode::Unsupported => 5,
        ErrorCode::Internal => 6,
        ErrorCode::Overloaded => 7,
        ErrorCode::ReadOnly => 8,
    }
}

/// Unknown bytes parse as `Internal`, mirroring the text parser's
/// forward-compatibility rule for unrecognized `code=` tokens.
fn error_code_from_byte(b: u8) -> ErrorCode {
    match b {
        0 => ErrorCode::Empty,
        1 => ErrorCode::UnknownCommand,
        2 => ErrorCode::BadArity,
        3 => ErrorCode::BadArg,
        4 => ErrorCode::NotFound,
        5 => ErrorCode::Unsupported,
        7 => ErrorCode::Overloaded,
        8 => ErrorCode::ReadOnly,
        _ => ErrorCode::Internal,
    }
}

/// Render a manifest as a v3 `OP_MSUBMIT` payload: a varint entry count,
/// then one packed record per entry — varint `user`, `qos` byte, `type`
/// byte, varint `tasks`, varint `cores_per_task`, `run_secs` as 8 raw
/// little-endian f64 bytes, varint `count`, varint tag length plus the tag
/// bytes (length 0 = no tag).
pub fn render_msubmit_v3(m: &Manifest) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + m.entries.len() * 16);
    write_uvarint(&mut out, m.entries.len() as u64);
    for e in &m.entries {
        write_uvarint(&mut out, u64::from(e.user));
        out.push(qos_byte(e.qos));
        out.push(job_type_byte(e.job_type));
        write_uvarint(&mut out, u64::from(e.tasks));
        write_uvarint(&mut out, u64::from(e.cores_per_task));
        out.extend_from_slice(&e.run_secs.to_le_bytes());
        write_uvarint(&mut out, u64::from(e.count));
        match &e.tag {
            Some(tag) => {
                write_uvarint(&mut out, tag.len() as u64);
                out.extend_from_slice(tag.as_bytes());
            }
            None => out.push(0),
        }
    }
    out
}

/// Parse a v3 `OP_MSUBMIT` payload into a typed [`Manifest`]. Reads
/// straight off the input slice — no per-entry line splitting or `String`
/// allocation (tags intern directly from the payload bytes). Wire-level
/// malformation rejects the whole request with a typed error, exactly like
/// the text grammar; semantic validation still happens per entry at
/// admission. `run_secs` carries raw f64 bits with no finiteness check —
/// the text grammar accepts `run_secs=NaN` too, and both are caught by
/// [`ManifestEntry::validate`].
pub fn parse_msubmit_v3(payload: &[u8]) -> Result<Manifest, ApiError> {
    let mut r = ByteReader::new(payload);
    let declared = r.uvarint("entries")?;
    if declared == 0 || declared > MAX_MANIFEST_ENTRIES as u64 {
        return Err(ApiError::bad_arg("entries", &declared.to_string()));
    }
    // A packed record is at least 15 bytes (five 1-byte varints, two
    // discriminant bytes, the 8-byte f64): a declared count the payload
    // cannot possibly carry is rejected before the entry Vec is sized.
    if declared.saturating_mul(15) > r.remaining() as u64 {
        return Err(ApiError::bad_arg(
            "entries",
            &format!("{declared} declared, {} payload bytes", r.remaining()),
        ));
    }
    let mut entries = Vec::with_capacity(declared as usize);
    for _ in 0..declared {
        let user = r.uvarint_u32("user")?;
        let qos = qos_from_byte(r.u8("qos")?)?;
        let job_type = job_type_from_byte(r.u8("type")?)?;
        let tasks = r.uvarint_u32("tasks")?;
        let cores_per_task = r.uvarint_u32("cores_per_task")?;
        let run_secs = r.f64("run_secs")?;
        let count = r.uvarint_u32("count")?;
        let tag_len = r.uvarint("tag")?;
        if tag_len > MAX_ENTRY_RECORD_BYTES as u64 {
            return Err(ApiError::bad_arg("tag", &format!("{tag_len} bytes")));
        }
        let tag = if tag_len == 0 {
            None
        } else {
            let raw = r.bytes(tag_len as usize, "tag")?;
            let s = std::str::from_utf8(raw)
                .map_err(|_| ApiError::bad_arg("tag", "invalid utf-8"))?;
            Some(Arc::from(s))
        };
        entries.push(ManifestEntry {
            user,
            qos,
            job_type,
            tasks,
            cores_per_task,
            run_secs,
            count,
            tag,
        });
    }
    r.done("manifest")?;
    Ok(Manifest { entries })
}

/// Render a manifest ack as a v3 `OP_MANIFEST_ACK` payload: varint
/// accepted/rejected counts, varint `jobs`, a has-manifest byte (1 =
/// varint id follows), then the accepted records (varint index/first/
/// last/count) and rejected records (varint index, error-code byte, varint
/// message length + UTF-8 message bytes).
pub fn render_manifest_ack_v3(a: &ManifestAck) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + a.accepted.len() * 8 + a.rejected.len() * 24);
    write_uvarint(&mut out, a.accepted.len() as u64);
    write_uvarint(&mut out, a.rejected.len() as u64);
    write_uvarint(&mut out, a.jobs);
    match a.manifest {
        Some(id) => {
            out.push(1);
            write_uvarint(&mut out, id);
        }
        None => out.push(0),
    }
    for acc in &a.accepted {
        write_uvarint(&mut out, u64::from(acc.index));
        write_uvarint(&mut out, acc.first);
        write_uvarint(&mut out, acc.last);
        write_uvarint(&mut out, acc.count);
    }
    for rej in &a.rejected {
        write_uvarint(&mut out, u64::from(rej.index));
        out.push(error_code_byte(rej.error.code));
        write_uvarint(&mut out, rej.error.message.len() as u64);
        out.extend_from_slice(rej.error.message.as_bytes());
    }
    out
}

/// Parse a v3 `OP_MANIFEST_ACK` payload, applying the same range sanity
/// checks as the text parser: per-record `last-first+1 == count` (checked
/// arithmetic) and records summing to the declared `jobs`, so a hostile or
/// buggy peer can never make the client iterate 2^64 job ids.
pub fn parse_manifest_ack_v3(payload: &[u8]) -> Result<ManifestAck, ApiError> {
    let mut r = ByteReader::new(payload);
    let n_acc = r.uvarint("accepted")?;
    let n_rej = r.uvarint("rejected")?;
    let jobs = r.uvarint("jobs")?;
    let manifest = match r.u8("manifest")? {
        0 => None,
        1 => Some(r.uvarint("manifest")?),
        other => return Err(ApiError::bad_arg("manifest", &other.to_string())),
    };
    let mut ack = ManifestAck {
        accepted: Vec::with_capacity((n_acc as usize).min(4096)),
        rejected: Vec::with_capacity((n_rej as usize).min(4096)),
        jobs,
        manifest,
    };
    let mut summed = 0u64;
    for _ in 0..n_acc {
        let acc = EntryAck {
            index: r.uvarint_u32("index")?,
            first: r.uvarint("first")?,
            last: r.uvarint("last")?,
            count: r.uvarint("count")?,
        };
        let span = acc
            .last
            .checked_sub(acc.first)
            .and_then(|d| d.checked_add(1));
        if span != Some(acc.count) {
            return Err(ApiError::new(
                ErrorCode::Internal,
                format!(
                    "manifest ack record has an inconsistent id range: \
                     first={} last={} count={}",
                    acc.first, acc.last, acc.count
                ),
            ));
        }
        summed = summed.saturating_add(acc.count);
        ack.accepted.push(acc);
    }
    for _ in 0..n_rej {
        let index = r.uvarint_u32("index")?;
        let code = error_code_from_byte(r.u8("code")?);
        let msg_len = r.uvarint("msg")?;
        let msg = std::str::from_utf8(r.bytes(msg_len as usize, "msg")?)
            .map_err(|_| ApiError::bad_arg("msg", "invalid utf-8"))?;
        ack.rejected.push(EntryReject {
            index,
            error: ApiError::new(code, msg),
        });
    }
    r.done("manifest ack")?;
    if summed != jobs {
        return Err(ApiError::new(
            ErrorCode::Internal,
            format!("manifest ack claims jobs={jobs} but its records sum to {summed}"),
        ));
    }
    Ok(ack)
}

#[cfg(test)]
mod tests {
    use super::*;
    use ProtocolVersion::{V1, V2, V21, V3};

    // ---- backward compatibility: the seed grammar, verbatim ----------------

    #[test]
    fn seed_v1_requests_still_parse() {
        // Every line here was accepted by the seed daemon.
        let r = parse_request("SUBMIT normal triple 4096 1 600", V1).unwrap();
        assert_eq!(
            r,
            Request::Submit(SubmitSpec {
                qos: QosClass::Normal,
                job_type: JobType::TripleMode,
                tasks: 4096,
                user: 1,
                run_secs: 600.0,
                count: 1,
            })
        );
        match parse_request("submit spot array 128 9", V1).unwrap() {
            Request::Submit(s) => {
                assert_eq!(s.run_secs, 3600.0);
                assert_eq!(s.qos, QosClass::Spot);
                assert_eq!(s.count, 1);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            parse_request("SQUEUE", V1).unwrap(),
            Request::Squeue(SqueueFilter::default())
        );
        assert_eq!(parse_request("ping", V1).unwrap(), Request::Ping);
        assert_eq!(parse_request("SCANCEL 42", V1).unwrap(), Request::Scancel(42));
        assert_eq!(parse_request("STATS", V1).unwrap(), Request::Stats);
        assert_eq!(parse_request("UTIL", V1).unwrap(), Request::Util);
        assert_eq!(parse_request("SHUTDOWN", V1).unwrap(), Request::Shutdown);
    }

    #[test]
    fn seed_v1_errors_keep_their_classes() {
        let code = |line: &str| parse_request(line, V1).unwrap_err().code;
        assert_eq!(code(""), ErrorCode::Empty);
        assert_eq!(code("FROBNICATE"), ErrorCode::UnknownCommand);
        assert_eq!(code("SUBMIT normal"), ErrorCode::BadArity);
        assert_eq!(code("SUBMIT normal warp 1 1"), ErrorCode::BadArg);
        assert_eq!(code("SUBMIT normal array 0 1"), ErrorCode::BadArg);
        // Degenerate batch count is a typed reject at the wire, both
        // versions (regression: count=0 must never ack an empty range).
        assert_eq!(code("SUBMIT normal array 4 1 60 0"), ErrorCode::BadArg);
        assert_eq!(
            parse_request("SUBMIT qos=normal type=array tasks=4 user=1 count=0", V2)
                .unwrap_err()
                .code,
            ErrorCode::BadArg
        );
        assert_eq!(code("SCANCEL x"), ErrorCode::BadArg);
    }

    // ---- request round-trips ----------------------------------------------

    #[test]
    fn v1_requests_roundtrip() {
        for line in [
            "SUBMIT normal triple 4096 1 600",
            "SUBMIT spot array 128 9 3600",
            "SUBMIT normal individual 1 7 60 10000",
            "SQUEUE",
            "SQUEUE user=1 qos=spot state=pending limit=10",
            "SJOB 7",
            "SCANCEL 42",
            "WAIT 1 2 3 30",
            "WAIT 9 0.5",
            "STATS",
            "UTIL",
            "HEALTH",
            "PING",
            "SHUTDOWN",
            "HELLO v2",
        ] {
            let req = parse_request(line, V1).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(render_request(&req, V1), line, "round-trip of {line:?}");
        }
    }

    #[test]
    fn v2_requests_roundtrip() {
        for line in [
            "SUBMIT qos=normal type=triple tasks=4096 user=1 run_secs=600 count=1",
            "SUBMIT qos=spot type=individual tasks=1 user=9 run_secs=3600 count=10000",
            "SQUEUE",
            "SQUEUE user=1 qos=spot state=pending limit=10",
            "SJOB id=7",
            "SCANCEL id=42",
            "WAIT jobs=1,2,3 timeout=30",
            "WAIT manifest=7 entry=2 timeout=30",
            "RESUME tag=nightly-batch",
            "RESUME manifest=12",
            "STATS",
            "UTIL",
            "HEALTH",
            "PING",
            "SHUTDOWN",
            "HELLO v2",
        ] {
            let req = parse_request(line, V2).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(render_request(&req, V2), line, "round-trip of {line:?}");
        }
    }

    #[test]
    fn v2_wait_empty_jobs_roundtrips() {
        // Regression: an empty jobs list is a legal WAIT (returns
        // immediately with dispatched=0) and must survive the wire.
        let req = Request::Wait {
            jobs: vec![],
            timeout_secs: 5.0,
        };
        let line = render_request(&req, V2);
        assert_eq!(line, "WAIT jobs= timeout=5");
        assert_eq!(parse_request(&line, V2).unwrap(), req);
    }

    #[test]
    fn msubmit_roundtrips_v2() {
        for line in [
            "MSUBMIT entries=0",
            "MSUBMIT entries=1;qos=normal type=triple tasks=608 user=1 cores_per_task=1 \
             run_secs=600 count=1",
            "MSUBMIT entries=2;qos=normal type=individual tasks=4 user=1 cores_per_task=1 \
             run_secs=60 count=2 tag=fig2-live;qos=spot type=array tasks=64 user=9 \
             cores_per_task=2 run_secs=3600 count=1",
        ] {
            // The literal above is wrapped for readability; the wire line
            // has single spaces.
            let line = line.split_whitespace().collect::<Vec<_>>().join(" ");
            let req = parse_request(&line, V2).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(render_request(&req, V2), line, "round-trip of {line:?}");
        }
        match parse_request(
            "MSUBMIT entries=1;qos=spot type=triple tasks=320 user=9 tag=backlog",
            V2,
        )
        .unwrap()
        {
            Request::MSubmit(m) => {
                assert_eq!(m.entries.len(), 1);
                assert_eq!(m.entries[0].cores_per_task, 1, "defaulted");
                assert_eq!(m.entries[0].run_secs, 3600.0, "defaulted");
                assert_eq!(m.entries[0].count, 1, "defaulted");
                assert_eq!(m.entries[0].tag.as_deref(), Some("backlog"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn v2_wait_entry_and_resume_parse() {
        assert_eq!(
            parse_request("WAIT manifest=7 entry=2", V2).unwrap(),
            Request::WaitEntry {
                manifest: 7,
                entry: 2,
                timeout_secs: 30.0,
            }
        );
        assert_eq!(
            parse_request("RESUME tag=night/batch:1", V2).unwrap(),
            Request::Resume(ResumeTarget::Tag("night/batch:1".into()))
        );
        assert_eq!(
            parse_request("RESUME manifest=4", V2).unwrap(),
            Request::Resume(ResumeTarget::Manifest(4))
        );
        let code = |line: &str| parse_request(line, V2).unwrap_err().code;
        // Exactly one of tag=/manifest= — zero or both are arity errors.
        assert_eq!(code("RESUME"), ErrorCode::BadArity);
        assert_eq!(code("RESUME tag=a manifest=1"), ErrorCode::BadArity);
        assert_eq!(code("RESUME manifest=x"), ErrorCode::BadArg);
        // The per-entry WAIT needs both keys; a garbled entry is typed.
        assert_eq!(code("WAIT manifest=7"), ErrorCode::BadArity);
        assert_eq!(code("WAIT manifest=7 entry=x"), ErrorCode::BadArg);
    }

    #[test]
    fn resume_is_rejected_on_v1_with_typed_unsupported() {
        let err = parse_request("RESUME tag=nightly", V1).unwrap_err();
        assert_eq!(err.code, ErrorCode::Unsupported);
        assert!(err.message.contains("HELLO v2"), "{err}");
    }

    #[test]
    fn msubmit_is_rejected_on_v1_with_typed_unsupported() {
        let err = parse_request(
            "MSUBMIT entries=1;qos=normal type=array tasks=4 user=1",
            V1,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::Unsupported);
        assert!(err.message.contains("HELLO v2"), "{err}");
    }

    #[test]
    fn msubmit_hostile_bodies_yield_typed_errors() {
        let code = |line: &str| parse_request(line, V2).unwrap_err().code;
        let entry = "qos=normal type=array tasks=4 user=1";
        // Truncated body: fewer records than declared.
        assert_eq!(code(&format!("MSUBMIT entries=2;{entry}")), ErrorCode::BadArity);
        // Padded body: more records than declared.
        assert_eq!(
            code(&format!("MSUBMIT entries=1;{entry};{entry}")),
            ErrorCode::BadArity
        );
        // Missing header.
        assert_eq!(code(&format!("MSUBMIT {entry}")), ErrorCode::BadArity);
        assert_eq!(code("MSUBMIT"), ErrorCode::BadArity);
        // Unparseable header count.
        assert_eq!(code(&format!("MSUBMIT entries=x;{entry}")), ErrorCode::BadArg);
        // Entry-count cap.
        assert_eq!(
            code(&format!("MSUBMIT entries={};{entry}", MAX_MANIFEST_ENTRIES + 1)),
            ErrorCode::BadArg
        );
        // Empty record (trailing separator).
        assert_eq!(code(&format!("MSUBMIT entries=1;{entry};")), ErrorCode::BadArity);
        assert_eq!(code("MSUBMIT entries=1;"), ErrorCode::BadArg);
        // Duplicate key inside one record.
        assert_eq!(
            code("MSUBMIT entries=1;qos=normal qos=spot type=array tasks=4 user=1"),
            ErrorCode::BadArg
        );
        // Unknown key.
        assert_eq!(
            code("MSUBMIT entries=1;qos=normal type=array tasks=4 user=1 bogus=1"),
            ErrorCode::BadArg
        );
        // Bare (non key=value) token.
        assert_eq!(
            code("MSUBMIT entries=1;qos=normal type=array tasks=4 user=1 loose"),
            ErrorCode::BadArg
        );
        // Missing required key.
        assert_eq!(
            code("MSUBMIT entries=1;qos=normal type=array tasks=4"),
            ErrorCode::BadArity
        );
        // Unparseable value.
        assert_eq!(
            code("MSUBMIT entries=1;qos=normal type=array tasks=many user=1"),
            ErrorCode::BadArg
        );
        // Overlong record.
        let long = format!(
            "MSUBMIT entries=1;qos=normal type=array tasks=4 user=1 tag={}",
            "x".repeat(MAX_ENTRY_RECORD_BYTES)
        );
        assert_eq!(code(&long), ErrorCode::BadArg);
    }

    #[test]
    fn msubmit_semantic_problems_parse_fine() {
        // Zero tasks/count parse at the wire level — admission rejects them
        // per entry (partial accept), not the whole request.
        match parse_request(
            "MSUBMIT entries=2;qos=normal type=array tasks=0 user=1;qos=spot type=triple \
             tasks=64 user=9 count=0",
            V2,
        )
        .unwrap()
        {
            Request::MSubmit(m) => {
                assert_eq!(m.entries[0].tasks, 0);
                assert_eq!(m.entries[1].count, 0);
                assert!(m.entries[0].validate().is_err());
                assert!(m.entries[1].validate().is_err());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn v2_jobs_rows_carry_tags_and_v1_drops_them() {
        let resp = Response::Jobs(vec![JobSummary {
            id: 7,
            job_type: JobType::Array,
            tasks: 64,
            user: 1,
            qos: QosClass::Normal,
            state: JobState::Running,
            tag: Some(Arc::from("fig2-live")),
        }]);
        let v2 = render_response(&resp, V2);
        assert!(v2.contains("tag=fig2-live"), "{v2}");
        assert_eq!(parse_response(&v2, V2).unwrap(), resp);
        let v1 = render_response(&resp, V1);
        assert!(!v1.contains("fig2-live"), "{v1}");
        match parse_response(&v1, V1).unwrap() {
            Response::Jobs(rows) => assert_eq!(rows[0].tag, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hostile_manifest_acks_are_rejected_by_the_client_parser() {
        // A malicious/buggy server must not be able to hand the client an
        // ack whose ranges would iterate astronomically or lie about jobs.
        let huge_range = format!(
            "OK kind=manifest_ack accepted=1 rejected=0 jobs=1\nacc index=0 first=0 last={} count=1",
            u64::MAX
        );
        for body in [
            // first > last.
            "OK kind=manifest_ack accepted=1 rejected=0 jobs=1\nacc index=0 first=5 last=4 count=1",
            // count disagrees with the range.
            "OK kind=manifest_ack accepted=1 rejected=0 jobs=2\nacc index=0 first=1 last=1 count=2",
            // 2^64-sized range.
            huge_range.as_str(),
            // jobs= does not match the record sum.
            "OK kind=manifest_ack accepted=1 rejected=0 jobs=99\nacc index=0 first=1 last=2 count=2",
            // declared record counts do not match the body.
            "OK kind=manifest_ack accepted=2 rejected=0 jobs=1\nacc index=0 first=1 last=1 count=1",
        ] {
            let err = parse_response(body, V2).expect_err(body);
            assert_eq!(err.code, ErrorCode::Internal, "{body}");
        }
    }

    #[test]
    fn hostile_resume_bodies_are_rejected_by_the_client_parser() {
        let near_max = u64::MAX - 1;
        let overflow = format!(
            "OK kind=resume manifest=1 entries=1\nent index=0 first={near_max} count=5 settled=0"
        );
        for body in [
            // settled exceeds the entry size.
            "OK kind=resume manifest=1 entries=1\nent index=0 first=1 count=2 settled=3",
            // first+count overflows u64 (astronomical iteration guard).
            overflow.as_str(),
            // declared entry count does not match the body.
            "OK kind=resume manifest=1 entries=2\nent index=0 first=1 count=1 settled=0",
        ] {
            let err = parse_response(body, V2).expect_err(body);
            assert_eq!(err.code, ErrorCode::Internal, "{body}");
        }
    }

    #[test]
    fn manifest_ack_without_manifest_id_still_parses() {
        // Forward compatibility: an ack from a pre-durability server lacks
        // the `manifest=` key — it parses as None on both versions.
        for v in [V1, V2] {
            let mut ack = ManifestAck::default();
            ack.manifest = Some(42);
            let wire = render_response(&Response::ManifestAck(ack), v);
            assert!(wire.contains("manifest=42"), "{wire}");
            let stripped = wire.replace(" manifest=42", "");
            match parse_response(&stripped, v).unwrap() {
                Response::ManifestAck(back) => assert_eq!(back.manifest, None),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn manifest_ack_reject_message_with_spaces_roundtrips() {
        let resp = Response::ManifestAck(ManifestAck {
            accepted: vec![],
            rejected: vec![EntryReject {
                index: 3,
                error: ApiError::bad_arg("run_secs", "not a number at all"),
            }],
            jobs: 0,
            manifest: None,
        });
        for v in [V1, V2] {
            let wire = render_response(&resp, v);
            assert_eq!(parse_response(&wire, v).unwrap(), resp, "{wire:?}");
        }
    }

    #[test]
    fn v2_submit_requires_core_keys() {
        assert_eq!(
            parse_request("SUBMIT qos=normal type=triple tasks=64", V2)
                .unwrap_err()
                .code,
            ErrorCode::BadArity
        );
        assert_eq!(
            parse_request("SUBMIT qos=normal type=triple tasks=64 user=1 bogus=3", V2)
                .unwrap_err()
                .code,
            ErrorCode::BadArg
        );
    }

    #[test]
    fn hello_negotiation_parses_in_both_versions() {
        for v in [V1, V2] {
            assert_eq!(
                parse_request("HELLO v2", v).unwrap(),
                Request::Hello(ProtocolVersion::V2)
            );
            assert_eq!(
                parse_request("HELLO v1", v).unwrap(),
                Request::Hello(ProtocolVersion::V1)
            );
        }
        assert_eq!(
            parse_request("HELLO v9", V1).unwrap_err().code,
            ErrorCode::BadArg
        );
    }

    // ---- response round-trips ---------------------------------------------

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Pong,
            Response::Hello(ProtocolVersion::V2),
            Response::ShuttingDown,
            Response::SubmitAck(SubmitAck {
                first: 1,
                last: 10_000,
                count: 10_000,
            }),
            Response::Cancelled(42),
            Response::Jobs(vec![
                JobSummary {
                    id: 3,
                    job_type: JobType::TripleMode,
                    tasks: 320,
                    user: 9,
                    qos: QosClass::Spot,
                    state: JobState::Running,
                    // None here: the v1 table cannot carry tags, and these
                    // samples round-trip under BOTH versions. Dedicated
                    // tests below cover Some(_) on the v2 wire.
                    tag: None,
                },
                JobSummary {
                    id: 4,
                    job_type: JobType::Array,
                    tasks: 64,
                    user: 1,
                    qos: QosClass::Normal,
                    state: JobState::Pending,
                    tag: None,
                },
            ]),
            Response::Jobs(Vec::new()),
            Response::Job(JobDetail {
                id: 7,
                job_type: JobType::Individual,
                tasks: 1,
                user: 4,
                qos: QosClass::Normal,
                state: JobState::Running,
                submit_secs: 1.5,
                queue_secs: 1.5,
                start_secs: Some(2.25),
                end_secs: None,
                requeues: 0,
                recognized_secs: Some(1.5),
                dispatched_secs: Some(2.25),
                latency_ns: Some(750_000_000),
                tag: Some(Arc::from("interactive")),
            }),
            Response::Wait(WaitResult {
                requested: 3,
                dispatched: 3,
                timed_out: false,
                latency_ns: 123_456_789,
            }),
            Response::Stats(StatsSnapshot {
                virtual_now_secs: 12.5,
                dispatches: 10,
                preemptions: 2,
                requeues: 2,
                cron_passes: 1,
                main_passes: 3,
                backfill_passes: 1,
                triggered_passes: 4,
                score_batches: 5,
                jobs_scored: 50,
                scorer: "native".into(),
                requests_ok: 20,
                requests_err: 1,
                jobs_submitted: 12,
                sched_latency_count: 8,
                sched_latency_p50_ns: 420_000_000,
                commands: [("submit".to_string(), 12u64), ("squeue".to_string(), 3u64)]
                    .into_iter()
                    .collect(),
                // None here: the contention block is a v2-only extension,
                // so the shared samples (round-tripped under BOTH versions)
                // must omit it. Dedicated tests below cover Some(_).
                contention: None,
                // Empty for the same reason: shard records are v2-only
                // continuation lines. Dedicated tests below cover them.
                shards: Vec::new(),
                // None for the same reason again: journal keys are v2-only.
                journal: None,
                // And the health block is v2-only too.
                health: None,
                // And the user-scale gauges.
                users: None,
            }),
            Response::Health(HealthReport {
                state: HealthState::Shedding,
                since_secs: 1.5,
                inflight: 12,
                inflight_budget: 64,
                shed_submits: 7,
                shed_msubmits: 2,
                rate_limited: 5,
                deadline_expired: 1,
                conns_evicted: 1,
                journal_poisoned: 0,
            }),
            Response::Util(UtilSnapshot {
                utilization: 0.25,
                idle_cores: 456,
                idle_nodes: 14,
                total_cores: 608,
                pending: 3,
                running: 2,
                shards: Vec::new(),
            }),
            Response::ChunkAck {
                part: 2,
                parts: 5,
                received: 24_000,
            },
            Response::Error(ApiError::not_found("unknown job 42")),
            Response::Error(ApiError::bad_arg("tasks", "0")),
            Response::ManifestAck(ManifestAck {
                accepted: vec![
                    EntryAck {
                        index: 0,
                        first: 1,
                        last: 608,
                        count: 608,
                    },
                    EntryAck {
                        index: 2,
                        first: 609,
                        last: 609,
                        count: 1,
                    },
                ],
                rejected: vec![EntryReject {
                    index: 1,
                    error: ApiError::bad_arg("tasks", "0"),
                }],
                jobs: 609,
                manifest: Some(3),
            }),
            Response::ManifestAck(ManifestAck::default()),
            Response::Resume(ResumeInfo {
                manifest: 3,
                entries: vec![
                    ResumeEntry {
                        index: 0,
                        first: 1,
                        count: 608,
                        settled: 608,
                        tag: Some(Arc::from("fig2-live")),
                    },
                    ResumeEntry {
                        index: 2,
                        first: 609,
                        count: 1,
                        settled: 0,
                        tag: None,
                    },
                ],
            }),
            Response::Resume(ResumeInfo {
                manifest: 9,
                entries: Vec::new(),
            }),
        ]
    }

    #[test]
    fn responses_roundtrip_v1() {
        for resp in sample_responses() {
            let wire = render_response(&resp, V1);
            let back = parse_response(&wire, V1).unwrap_or_else(|e| panic!("{wire:?}: {e}"));
            assert_eq!(back, resp, "v1 wire: {wire:?}");
        }
    }

    #[test]
    fn responses_roundtrip_v2() {
        for resp in sample_responses() {
            let wire = render_response(&resp, V2);
            let back = parse_response(&wire, V2).unwrap_or_else(|e| panic!("{wire:?}: {e}"));
            assert_eq!(back, resp, "v2 wire: {wire:?}");
        }
    }

    fn stats_with_contention() -> StatsSnapshot {
        let mut s = match sample_responses().remove(9) {
            Response::Stats(s) => s,
            other => panic!("sample 9 is stats, got {other:?}"),
        };
        s.contention = Some(ContentionStats {
            read_path_ops: 123,
            write_locks: 45,
            waits_parked: 6,
            waits_resumed: 6,
            lock_hold_count: 45,
            lock_hold_p50_ns: 12_000,
            lock_hold_p99_ns: 98_000,
            lock_hold_max_ns: 250_000,
        });
        s
    }

    #[test]
    fn stats_contention_extension_roundtrips_v2() {
        let resp = Response::Stats(stats_with_contention());
        let wire = render_response(&resp, V2);
        for key in [
            "read_path_ops=123",
            "write_locks=45",
            "waits_parked=6",
            "waits_resumed=6",
            "lock_hold_count=45",
            "lock_hold_p50_ns=12000",
            "lock_hold_p99_ns=98000",
            "lock_hold_max_ns=250000",
        ] {
            assert!(wire.contains(key), "missing {key} in {wire}");
        }
        assert_eq!(parse_response(&wire, V2).unwrap(), resp);
    }

    #[test]
    fn stats_contention_extension_is_dropped_on_v1() {
        // v1 keeps its original key set byte-compatible: the extension is
        // not rendered, and a v1 parse naturally yields None.
        let resp = Response::Stats(stats_with_contention());
        let wire = render_response(&resp, V1);
        assert!(!wire.contains("read_path_ops="), "{wire}");
        assert!(!wire.contains("lock_hold_p99_ns="), "{wire}");
        match parse_response(&wire, V1).unwrap() {
            Response::Stats(s) => assert_eq!(s.contention, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn v2_stats_without_contention_keys_still_parses() {
        // Forward compatibility: a v2 response from a pre-extension server
        // lacks the keys entirely — the block parses as None.
        let mut s = stats_with_contention();
        s.contention = None;
        let wire = render_response(&Response::Stats(s.clone()), V2);
        match parse_response(&wire, V2).unwrap() {
            Response::Stats(back) => assert_eq!(back, s),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_journal_extension_roundtrips_v2_and_drops_on_v1() {
        let mut s = stats_with_contention();
        s.journal = Some(JournalStats {
            appends: 32,
            synced_appends: 32,
            group_commits: 5,
            poisoned: 0,
        });
        let resp = Response::Stats(s.clone());
        let wire = render_response(&resp, V2);
        for key in [
            "journal_appends=32",
            "journal_synced_appends=32",
            "journal_group_commits=5",
            "journal_poisoned=0",
        ] {
            assert!(wire.contains(key), "missing {key} in {wire}");
        }
        assert_eq!(parse_response(&wire, V2).unwrap(), resp);
        // v1 keeps its original key set byte-compatible: no journal keys on
        // the wire, and a v1 parse naturally yields None.
        let v1 = render_response(&resp, V1);
        assert!(!v1.contains("journal_appends="), "{v1}");
        match parse_response(&v1, V1).unwrap() {
            Response::Stats(back) => assert_eq!(back.journal, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn v1_squeue_table_is_byte_compatible_with_seed() {
        let resp = Response::Jobs(vec![JobSummary {
            id: 1,
            job_type: JobType::TripleMode,
            tasks: 320,
            user: 9,
            qos: QosClass::Spot,
            state: JobState::Pending,
            // A tag must NOT leak into the seed-compatible v1 table.
            tag: Some(Arc::from("spot-fill")),
        }]);
        assert_eq!(
            render_response(&resp, V1),
            "OK \nJOBID TYPE TASKS USER QOS STATE\n1 triple-mode 320 user9 spot Pending\n(1 jobs)"
        );
    }

    #[test]
    fn v1_error_rendering_keeps_err_prefix() {
        let wire = render_response(&Response::Error(ApiError::unknown_command("FROB")), V1);
        assert!(wire.starts_with("ERR "), "{wire}");
        assert!(wire.contains("unknown_command"), "{wire}");
    }

    // ---- v2.1: chunked MSUBMIT and shard records ----------------------------

    #[test]
    fn v21_parses_every_v2_form_identically() {
        for line in [
            "SUBMIT qos=normal type=triple tasks=4096 user=1 run_secs=600 count=1",
            "SQUEUE user=1 qos=spot state=pending limit=10",
            "SJOB id=7",
            "SCANCEL id=42",
            "WAIT jobs=1,2,3 timeout=30",
            "WAIT manifest=7 entry=2 timeout=30",
            "RESUME tag=nightly-batch",
            "MSUBMIT entries=1;qos=normal type=array tasks=4 user=1 cores_per_task=1 run_secs=60 count=1",
            "STATS",
            "UTIL",
            "HELLO v2.1",
        ] {
            let on_v2 = parse_request(line, V2);
            let on_v21 = parse_request(line, V21).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(Some(&on_v21), on_v2.as_ref().ok(), "{line}");
            assert_eq!(render_request(&on_v21, V21), line, "round-trip of {line:?}");
        }
    }

    #[test]
    fn chunked_msubmit_roundtrips_on_v21() {
        let entry = "qos=normal type=array tasks=4 user=1 cores_per_task=1 run_secs=60 count=1";
        let line = format!("MSUBMIT entries=5 part=2/3;{entry};{entry}");
        let req = parse_request(&line, V21).unwrap();
        match &req {
            Request::MSubmitChunk(c) => {
                assert_eq!((c.entries, c.part, c.parts), (5, 2, 3));
                assert_eq!(c.records.len(), 2);
                assert_eq!(c.records[0].tasks, 4);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(req.command_name(), "MSUBMIT");
        assert_eq!(render_request(&req, V21), line);
    }

    #[test]
    fn chunked_msubmit_lifts_the_single_line_entry_cap() {
        // The declared total of a chunked stream may exceed the single-line
        // cap (the point of chunking); only the assembled cap applies.
        let entry = "qos=normal type=individual tasks=1 user=1";
        let over_line_cap = MAX_MANIFEST_ENTRIES + 1;
        let line = format!("MSUBMIT entries={over_line_cap} part=1/2;{entry}");
        assert!(matches!(
            parse_request(&line, V21).unwrap(),
            Request::MSubmitChunk(_)
        ));
        // …but the assembled-manifest cap still binds the declaration.
        let over_chunk_cap = MAX_CHUNKED_MANIFEST_ENTRIES + 1;
        let line = format!("MSUBMIT entries={over_chunk_cap} part=1/2;{entry}");
        assert_eq!(parse_request(&line, V21).unwrap_err().code, ErrorCode::BadArg);
        // An unchunked line keeps the original cap, even on v2.1.
        let line = format!("MSUBMIT entries={over_line_cap};{entry}");
        assert_eq!(parse_request(&line, V21).unwrap_err().code, ErrorCode::BadArg);
    }

    #[test]
    fn chunked_msubmit_is_rejected_below_v21() {
        let line = "MSUBMIT entries=4 part=1/2;qos=normal type=array tasks=4 user=1";
        let err = parse_request(line, V2).unwrap_err();
        assert_eq!(err.code, ErrorCode::Unsupported);
        assert!(err.message.contains("v2.1"), "{err}");
        // v1 keeps its blanket MSUBMIT rejection.
        assert_eq!(parse_request(line, V1).unwrap_err().code, ErrorCode::Unsupported);
    }

    #[test]
    fn chunked_msubmit_hostile_headers_yield_typed_errors() {
        let code = |line: &str| parse_request(line, V21).unwrap_err().code;
        let entry = "qos=normal type=array tasks=4 user=1";
        // Malformed part tokens.
        assert_eq!(code(&format!("MSUBMIT entries=4 part=;{entry}")), ErrorCode::BadArg);
        assert_eq!(code(&format!("MSUBMIT entries=4 part=1;{entry}")), ErrorCode::BadArg);
        assert_eq!(code(&format!("MSUBMIT entries=4 part=x/2;{entry}")), ErrorCode::BadArg);
        // Zero / out-of-range positions.
        assert_eq!(code(&format!("MSUBMIT entries=4 part=0/2;{entry}")), ErrorCode::BadArg);
        assert_eq!(code(&format!("MSUBMIT entries=4 part=3/2;{entry}")), ErrorCode::BadArg);
        assert_eq!(code(&format!("MSUBMIT entries=4 part=1/0;{entry}")), ErrorCode::BadArg);
        // Part count over the stream cap.
        assert_eq!(
            code(&format!(
                "MSUBMIT entries=4 part=1/{};{entry}",
                MAX_CHUNK_PARTS + 1
            )),
            ErrorCode::BadArg
        );
        // A stray non-part token in the header.
        assert_eq!(code(&format!("MSUBMIT entries=4 bogus=1;{entry}")), ErrorCode::BadArg);
        assert_eq!(
            code(&format!("MSUBMIT entries=4 part=1/2 extra=1;{entry}")),
            ErrorCode::BadArity
        );
        // A chunk carrying more records than the declared total.
        assert_eq!(
            code(&format!("MSUBMIT entries=1 part=1/2;{entry};{entry}")),
            ErrorCode::BadArity
        );
    }

    #[test]
    fn chunk_ack_roundtrips_both_versions() {
        let resp = Response::ChunkAck {
            part: 3,
            parts: 7,
            received: 36_000,
        };
        for v in [V1, V2, V21] {
            let wire = render_response(&resp, v);
            assert!(wire.contains("part=3"), "{wire}");
            assert!(wire.contains("received=36000"), "{wire}");
            assert_eq!(parse_response(&wire, v).unwrap(), resp, "{wire:?}");
        }
    }

    fn sample_shard_stats() -> Vec<ShardStats> {
        vec![
            ShardStats {
                kind: ShardKind::Reactor,
                index: 0,
                label: "reactor".into(),
                wakeups: 120,
                events: 340,
                connections: 9,
                parked: 2,
                queue_depth: 0,
                lock_hold_p99_ns: 0,
            },
            ShardStats {
                kind: ShardKind::Sched,
                index: 0,
                label: "interactive".into(),
                wakeups: 55,
                events: 48,
                connections: 0,
                parked: 0,
                queue_depth: 3,
                lock_hold_p99_ns: 84_000,
            },
            ShardStats {
                kind: ShardKind::Sched,
                index: 1,
                label: "spot".into(),
                wakeups: 31,
                events: 12,
                connections: 0,
                parked: 0,
                queue_depth: 17,
                lock_hold_p99_ns: 96_500,
            },
        ]
    }

    #[test]
    fn stats_shard_records_roundtrip_v2_and_drop_on_v1() {
        let mut s = stats_with_contention();
        s.shards = sample_shard_stats();
        let resp = Response::Stats(s.clone());
        for v in [V2, V21] {
            let wire = render_response(&resp, v);
            assert!(wire.contains("\nshard kind=reactor index=0 label=reactor"), "{wire}");
            assert!(wire.contains("kind=sched index=1 label=spot"), "{wire}");
            assert!(wire.contains("queue_depth=17"), "{wire}");
            assert_eq!(parse_response(&wire, v).unwrap(), resp, "{wire:?}");
        }
        // v1 keeps its single-line byte-compatible record: no shard lines,
        // and a v1 parse naturally yields the empty vec.
        let wire = render_response(&resp, V1);
        assert!(!wire.contains("shard "), "{wire}");
        match parse_response(&wire, V1).unwrap() {
            Response::Stats(back) => assert!(back.shards.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn util_shard_records_roundtrip_v2_and_drop_on_v1() {
        let resp = Response::Util(UtilSnapshot {
            utilization: 0.5,
            idle_cores: 304,
            idle_nodes: 9,
            total_cores: 608,
            pending: 20,
            running: 5,
            shards: vec![
                ShardUtil {
                    index: 0,
                    label: "interactive".into(),
                    utilization: 0.75,
                    idle_cores: 76,
                    total_cores: 304,
                    pending: 2,
                    running: 4,
                },
                ShardUtil {
                    index: 1,
                    label: "spot".into(),
                    utilization: 0.25,
                    idle_cores: 228,
                    total_cores: 304,
                    pending: 18,
                    running: 1,
                },
            ],
        });
        for v in [V2, V21] {
            let wire = render_response(&resp, v);
            assert!(wire.contains("\nshard index=0 label=interactive"), "{wire}");
            assert!(wire.contains("label=spot"), "{wire}");
            assert_eq!(parse_response(&wire, v).unwrap(), resp, "{wire:?}");
        }
        let wire = render_response(&resp, V1);
        assert!(!wire.contains("shard "), "{wire}");
        match parse_response(&wire, V1).unwrap() {
            Response::Util(back) => assert!(back.shards.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    // ---- overload control plane: errors, health, deadlines ------------------

    #[test]
    fn overloaded_error_retry_hint_roundtrips_v2_and_drops_on_v1() {
        let resp = Response::Error(ApiError::overloaded("admission budget exhausted", 250));
        let wire = render_response(&resp, V2);
        // Machine keys precede the greedy msg= field.
        assert_eq!(
            wire,
            "ERR code=overloaded retry_after_ms=250 msg=admission budget exhausted"
        );
        assert_eq!(parse_response(&wire, V2).unwrap(), resp);
        // v1 renders the plain seed-shaped error; the hint parses as None.
        let v1 = render_response(&resp, V1);
        assert_eq!(v1, "ERR overloaded: admission budget exhausted");
        match parse_response(&v1, V1).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.code, ErrorCode::Overloaded);
                assert_eq!(e.retry_after_ms, None);
            }
            other => panic!("{other:?}"),
        }
        // An error without the hint keeps the pre-overload v2 shape.
        let plain = Response::Error(ApiError::read_only("journal poisoned"));
        assert_eq!(
            render_response(&plain, V2),
            "ERR code=read_only msg=journal poisoned"
        );
        assert_eq!(parse_response("ERR code=read_only msg=journal poisoned", V2).unwrap(), plain);
    }

    #[test]
    fn stats_health_extension_roundtrips_v2_and_drops_on_v1() {
        let mut s = stats_with_contention();
        s.health = Some(HealthReport {
            state: HealthState::Shedding,
            since_secs: 0.25,
            inflight: 3,
            inflight_budget: 64,
            shed_submits: 11,
            shed_msubmits: 4,
            rate_limited: 9,
            deadline_expired: 2,
            conns_evicted: 1,
            journal_poisoned: 0,
        });
        let resp = Response::Stats(s.clone());
        let wire = render_response(&resp, V2);
        for key in [
            "health_state=shedding",
            "health_since_secs=0.25",
            "health_inflight=3",
            "health_inflight_budget=64",
            "shed_submits=11",
            "shed_msubmits=4",
            "shed_rate_limited=9",
            "shed_deadline_expired=2",
            "shed_conns_evicted=1",
            "health_journal_poisoned=0",
        ] {
            assert!(wire.contains(key), "missing {key} in {wire}");
        }
        assert_eq!(parse_response(&wire, V2).unwrap(), resp);
        // v1 keeps its original key set byte-compatible.
        let v1 = render_response(&resp, V1);
        assert!(!v1.contains("health_state="), "{v1}");
        assert!(!v1.contains("shed_submits="), "{v1}");
        match parse_response(&v1, V1).unwrap() {
            Response::Stats(back) => assert_eq!(back.health, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_users_extension_roundtrips_v2_and_drops_on_v1() {
        let mut s = stats_with_contention();
        s.users = Some(UserScaleStats {
            users_active: 250_000,
            users_tracked: 1_000_000,
            buckets_live: 4_096,
        });
        let resp = Response::Stats(s.clone());
        for v in [V2, V21, V3] {
            let wire = render_response(&resp, v);
            for key in [
                "users_active=250000",
                "users_tracked=1000000",
                "buckets_live=4096",
            ] {
                assert!(wire.contains(key), "missing {key} in {wire}");
            }
            assert_eq!(parse_response(&wire, v).unwrap(), resp, "{wire:?}");
        }
        // v1 keeps its original key set byte-compatible; a v2 body from an
        // older server (no users keys) parses as None.
        let v1 = render_response(&resp, V1);
        assert!(!v1.contains("users_active="), "{v1}");
        match parse_response(&v1, V1).unwrap() {
            Response::Stats(back) => assert_eq!(back.users, None),
            other => panic!("{other:?}"),
        }
        let mut without = stats_with_contention();
        without.users = None;
        let wire = render_response(&Response::Stats(without.clone()), V2);
        assert_eq!(parse_response(&wire, V2).unwrap(), Response::Stats(without));
    }

    #[test]
    fn health_verb_parses_in_every_version() {
        for v in [V1, V2, V21, V3] {
            assert_eq!(parse_request("HEALTH", v).unwrap(), Request::Health);
            assert_eq!(parse_request("health", v).unwrap(), Request::Health);
        }
    }

    #[test]
    fn deadline_prefix_splits_on_v2_and_passes_through_on_v1() {
        // v2: the prefix strips and the remainder is the verb line.
        let (ms, rest) = split_deadline("deadline_ms=250 WAIT jobs=1 timeout=5", V2).unwrap();
        assert_eq!(ms, Some(250));
        assert_eq!(rest, "WAIT jobs=1 timeout=5");
        assert!(matches!(
            parse_request(rest, V2).unwrap(),
            Request::Wait { .. }
        ));
        // Lines without the prefix pass through untouched.
        let (ms, rest) = split_deadline("STATS", V2).unwrap();
        assert_eq!((ms, rest), (None, "STATS"));
        // v1 never grew the token: the line passes through verbatim (and
        // the verb parser then rejects it as an unknown command).
        let line = "deadline_ms=250 PING";
        let (ms, rest) = split_deadline(line, V1).unwrap();
        assert_eq!((ms, rest), (None, line));
        assert_eq!(
            parse_request(line, V1).unwrap_err().code,
            ErrorCode::UnknownCommand
        );
        // Hostile values are typed errors.
        assert_eq!(
            split_deadline("deadline_ms=x PING", V2).unwrap_err().code,
            ErrorCode::BadArg
        );
        assert_eq!(
            split_deadline("deadline_ms=0 PING", V2).unwrap_err().code,
            ErrorCode::BadArg
        );
        // A bare deadline with no verb is an empty request downstream.
        let (ms, rest) = split_deadline("deadline_ms=10", V2).unwrap();
        assert_eq!((ms, rest), (Some(10), ""));
        assert_eq!(parse_request(rest, V2).unwrap_err().code, ErrorCode::Empty);
        // Chunked MSUBMIT keeps its body grammar intact after the strip.
        let line = "deadline_ms=50 MSUBMIT entries=4 part=1/2;qos=normal type=array tasks=4 user=1";
        let (ms, rest) = split_deadline(line, V21).unwrap();
        assert_eq!(ms, Some(50));
        assert!(matches!(
            parse_request(rest, V21).unwrap(),
            Request::MSubmitChunk(_)
        ));
    }

    #[test]
    fn v2_stats_without_shard_lines_still_parses() {
        // Forward compatibility: a pre-sharding v2 server emits no shard
        // lines — the vec parses empty rather than erroring.
        let wire = "OK kind=stats virtual_now_secs=1 dispatches=0 preemptions=0 requeues=0 \
                    cron_passes=0 main_passes=0 backfill_passes=0 triggered_passes=0 \
                    score_batches=0 jobs_scored=0 scorer=native requests_ok=0 requests_err=0 \
                    jobs_submitted=0 sched_latency_count=0 sched_latency_p50_ns=0";
        match parse_response(wire, V2).unwrap() {
            Response::Stats(s) => assert!(s.shards.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    // ---- v3 binary framing --------------------------------------------------

    #[test]
    fn v3_text_bodies_are_exactly_v2() {
        // Every text-opcode body parses and renders byte-identically to the
        // v2.1 grammar: the binary dialect changes framing, never grammar.
        for line in [
            "PING",
            "STATS",
            "HEALTH",
            "UTIL",
            "SHUTDOWN",
            "HELLO v2",
            "SQUEUE qos=spot",
            "SUBMIT qos=normal type=triple tasks=4096 user=1 run_secs=600 count=2",
            "SJOB id=7",
            "SCANCEL id=3",
            "WAIT jobs=3 timeout=5",
            "MSUBMIT qos=normal type=array tasks=8 user=1;qos=spot type=individual tasks=4 \
             user=9 tag=t1",
        ] {
            let v3 = parse_request(line, V3).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(v3, parse_request(line, V2).unwrap(), "{line}");
            assert_eq!(render_request(&v3, V3), render_request(&v3, V2), "{line}");
        }
        // The chunked MSUBMIT body is v2.1 grammar; v3 keeps it verbatim.
        let chunked = "MSUBMIT entries=4 part=1/2;qos=normal type=array tasks=4 user=1";
        let v3 = parse_request(chunked, V3).unwrap();
        assert_eq!(v3, parse_request(chunked, V21).unwrap());
        assert_eq!(render_request(&v3, V3), render_request(&v3, V21));
        // Response bodies render exactly as v2 and round-trip under V3.
        for resp in sample_responses() {
            let wire = render_response(&resp, V3);
            assert_eq!(wire, render_response(&resp, V2));
            assert_eq!(parse_response(&wire, V3).unwrap(), resp, "{wire:?}");
        }
    }

    #[test]
    fn v3_frame_header_roundtrips_and_guards_length() {
        let frame = v3_frame(OP_TEXT_REQ, b"PING");
        assert_eq!(frame.len(), FRAME_HEADER_BYTES + 5);
        assert_eq!(decode_frame_header(&frame).unwrap(), Some(5));
        assert_eq!(frame[FRAME_HEADER_BYTES], OP_TEXT_REQ);
        assert_eq!(&frame[FRAME_HEADER_BYTES + 1..], b"PING");
        // Empty payload frames as len=1 (the opcode byte).
        assert_eq!(decode_frame_header(&v3_frame(OP_TEXT_RESP, b"")).unwrap(), Some(1));
        // A partial header asks for more bytes.
        assert_eq!(decode_frame_header(&frame[..3]).unwrap(), None);
        assert_eq!(decode_frame_header(&[]).unwrap(), None);
        // Zero and oversized lengths are typed errors (desync → close).
        assert_eq!(
            decode_frame_header(&0u32.to_le_bytes()).unwrap_err().code,
            ErrorCode::BadArity
        );
        let over = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        assert!(decode_frame_header(&over).is_err());
        let max = (MAX_FRAME_BYTES as u32).to_le_bytes();
        assert_eq!(decode_frame_header(&max).unwrap(), Some(MAX_FRAME_BYTES));
    }

    #[test]
    fn uvarints_roundtrip_and_reject_overlong_encodings() {
        for v in [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, 1 << 63, u64::MAX] {
            let mut buf = Vec::new();
            write_uvarint(&mut buf, v);
            assert!(buf.len() <= 10, "{v}");
            let mut r = ByteReader::new(&buf);
            assert_eq!(r.uvarint("v").unwrap(), v);
            r.done("v").unwrap();
        }
        // A 10th byte above 1 would overflow u64.
        let mut r = ByteReader::new(&[0xff; 10]);
        assert_eq!(r.uvarint("v").unwrap_err().code, ErrorCode::BadArg);
        // An 11-byte encoding overflows regardless of its bits.
        let eleven = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x01];
        let mut r = ByteReader::new(&eleven);
        assert_eq!(r.uvarint("v").unwrap_err().code, ErrorCode::BadArg);
        // A dangling continuation bit is truncation, not silence.
        let mut r = ByteReader::new(&[0x80]);
        assert_eq!(r.uvarint("v").unwrap_err().code, ErrorCode::BadArity);
    }

    fn random_manifest(rng: &mut crate::util::rng::Xoshiro256, entries: usize) -> Manifest {
        let mut m = Manifest::default();
        for i in 0..entries {
            let qos = if rng.gen_range(0, 2) == 0 {
                QosClass::Normal
            } else {
                QosClass::Spot
            };
            let job_type = match rng.gen_range(0, 3) {
                0 => JobType::Individual,
                1 => JobType::Array,
                _ => JobType::TripleMode,
            };
            let tag = match rng.gen_range(0, 3) {
                0 => None,
                1 => Some(Arc::from("fig2-live")),
                _ => Some(Arc::from(format!("u{i}-tag.x/y:z"))),
            };
            m.entries.push(ManifestEntry {
                user: rng.gen_range(0, 1 << 20) as u32,
                qos,
                job_type,
                tasks: rng.gen_range(1, 4097) as u32,
                cores_per_task: rng.gen_range(1, 5) as u32,
                run_secs: rng.gen_range(1, 7200) as f64 * 0.5,
                count: rng.gen_range(1, 9) as u32,
                tag,
            });
        }
        m
    }

    #[test]
    fn v3_msubmit_roundtrips_random_manifests_and_matches_text() {
        let mut rng = crate::util::rng::Xoshiro256::new(0xb13a_57ee);
        for entries in [1usize, 2, 7, 64, 500] {
            let m = random_manifest(&mut rng, entries);
            let payload = render_msubmit_v3(&m);
            let back = parse_msubmit_v3(&payload).unwrap_or_else(|e| panic!("{entries}: {e}"));
            assert_eq!(back, m, "binary round-trip at {entries} entries");
            // The binary parse admits exactly what the text parse admits.
            let line = render_request(&Request::MSubmit(m.clone()), V2);
            match parse_request(&line, V2).unwrap() {
                Request::MSubmit(text) => assert_eq!(text, back, "{entries} entries"),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn v3_msubmit_carries_raw_f64_bits() {
        // The text grammar accepts `run_secs=NaN`; the binary record carries
        // the raw bits the same way. Both are refused later by semantic
        // validation, never by the codec.
        let entry =
            ManifestEntry::new(QosClass::Spot, JobType::Array, 4, 1).with_run_secs(f64::NAN);
        let m = Manifest {
            entries: vec![entry],
        };
        let back = parse_msubmit_v3(&render_msubmit_v3(&m)).unwrap();
        assert!(back.entries[0].run_secs.is_nan());
        assert!(back.entries[0].validate().is_err());
    }

    #[test]
    fn hostile_v3_msubmit_payloads_are_typed_errors() {
        let mut m = Manifest::default();
        m.entries.push(ManifestEntry::new(QosClass::Normal, JobType::Array, 8, 3));
        let good = render_msubmit_v3(&m);
        parse_msubmit_v3(&good).unwrap();

        // Truncated mid-record.
        for cut in 1..good.len() {
            let err = parse_msubmit_v3(&good[..cut]).expect_err("truncation must error");
            assert!(
                matches!(err.code, ErrorCode::BadArity | ErrorCode::BadArg),
                "cut at {cut}: {err}"
            );
        }
        // Trailing bytes after the declared entries.
        let mut trailing = good.clone();
        trailing.push(0);
        assert_eq!(parse_msubmit_v3(&trailing).unwrap_err().code, ErrorCode::BadArity);
        // Zero declared entries.
        assert_eq!(parse_msubmit_v3(&[0x00]).unwrap_err().code, ErrorCode::BadArg);
        // Declared count over the manifest cap.
        let mut over = Vec::new();
        write_uvarint(&mut over, MAX_MANIFEST_ENTRIES as u64 + 1);
        assert_eq!(parse_msubmit_v3(&over).unwrap_err().code, ErrorCode::BadArg);
        // A declared count the payload cannot possibly carry is refused
        // before any allocation.
        let mut impossible = Vec::new();
        write_uvarint(&mut impossible, 100);
        assert_eq!(parse_msubmit_v3(&impossible).unwrap_err().code, ErrorCode::BadArg);
        // Unknown discriminant bytes. Record layout for a sub-128 user:
        // [n][user][qos][type]... — qos at offset 2, type at offset 3.
        let mut bad_qos = good.clone();
        bad_qos[2] = 7;
        let err = parse_msubmit_v3(&bad_qos).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadArg);
        assert!(err.message.contains("qos"), "{err}");
        let mut bad_type = good.clone();
        bad_type[3] = 9;
        let err = parse_msubmit_v3(&bad_type).unwrap_err();
        assert!(err.message.contains("type"), "{err}");
        // A varint entry count that overflows u64.
        assert_eq!(parse_msubmit_v3(&[0xff; 10]).unwrap_err().code, ErrorCode::BadArg);
        // The final byte of `good` is the tag-length varint (0 = no tag):
        // an oversized declared tag is refused before reading tag bytes...
        let mut big_tag = good[..good.len() - 1].to_vec();
        write_uvarint(&mut big_tag, MAX_ENTRY_RECORD_BYTES as u64 + 44);
        let err = parse_msubmit_v3(&big_tag).unwrap_err();
        assert!(err.message.contains("tag"), "{err}");
        // ...and tag bytes must be UTF-8.
        let mut bad_utf8 = good[..good.len() - 1].to_vec();
        bad_utf8.extend_from_slice(&[0x02, 0xff, 0xfe]);
        let err = parse_msubmit_v3(&bad_utf8).unwrap_err();
        assert!(err.message.contains("utf-8"), "{err}");
    }

    #[test]
    fn v3_manifest_ack_roundtrips() {
        let acks = [
            ManifestAck::default(),
            ManifestAck {
                accepted: vec![
                    EntryAck {
                        index: 0,
                        first: 1,
                        last: 608,
                        count: 608,
                    },
                    EntryAck {
                        index: 2,
                        first: 609,
                        last: 609,
                        count: 1,
                    },
                ],
                rejected: vec![EntryReject {
                    index: 1,
                    error: ApiError::bad_arg("run_secs", "not a number at all"),
                }],
                jobs: 609,
                manifest: Some(3),
            },
            ManifestAck {
                accepted: vec![],
                rejected: vec![EntryReject {
                    index: 0,
                    error: ApiError::new(ErrorCode::Overloaded, ""),
                }],
                jobs: 0,
                manifest: None,
            },
        ];
        for ack in acks {
            let payload = render_manifest_ack_v3(&ack);
            assert_eq!(parse_manifest_ack_v3(&payload).unwrap(), ack);
        }
    }

    #[test]
    fn hostile_v3_manifest_acks_are_rejected_by_the_client_parser() {
        fn ack_head(n_acc: u64, n_rej: u64, jobs: u64) -> Vec<u8> {
            let mut out = Vec::new();
            write_uvarint(&mut out, n_acc);
            write_uvarint(&mut out, n_rej);
            write_uvarint(&mut out, jobs);
            out.push(0);
            out
        }
        // Inconsistent id range (first > last).
        let mut bad_range = ack_head(1, 0, 5);
        for v in [0u64, 10, 5, 5] {
            write_uvarint(&mut bad_range, v);
        }
        let err = parse_manifest_ack_v3(&bad_range).unwrap_err();
        assert_eq!(err.code, ErrorCode::Internal);
        assert!(err.message.contains("inconsistent"), "{err}");
        // A full-u64 span must not wrap into plausibility.
        let mut wrap = ack_head(1, 0, 5);
        for v in [0u64, u64::MAX - 1] {
            write_uvarint(&mut wrap, v);
        }
        write_uvarint(&mut wrap, 3);
        write_uvarint(&mut wrap, 5);
        assert_eq!(parse_manifest_ack_v3(&wrap).unwrap_err().code, ErrorCode::Internal);
        // Records must sum to the declared jobs.
        let mut short = ack_head(1, 0, 7);
        for v in [0u64, 1, 5, 5] {
            write_uvarint(&mut short, v);
        }
        let err = parse_manifest_ack_v3(&short).unwrap_err();
        assert!(err.message.contains("sum"), "{err}");
        // Unknown has-manifest discriminant.
        let mut bad_flag = Vec::new();
        for v in [0u64, 0, 0] {
            write_uvarint(&mut bad_flag, v);
        }
        bad_flag.push(9);
        assert_eq!(parse_manifest_ack_v3(&bad_flag).unwrap_err().code, ErrorCode::BadArg);
        // Trailing bytes after the declared records.
        let mut trailing = render_manifest_ack_v3(&ManifestAck::default());
        trailing.push(0);
        assert_eq!(parse_manifest_ack_v3(&trailing).unwrap_err().code, ErrorCode::BadArity);
        // An unknown reject-code byte parses as Internal (forward compat),
        // mirroring the text parser's unknown-token rule.
        let mut unknown_code = ack_head(0, 1, 0);
        write_uvarint(&mut unknown_code, 4);
        unknown_code.push(0xee);
        write_uvarint(&mut unknown_code, 2);
        unknown_code.extend_from_slice(b"hm");
        let ack = parse_manifest_ack_v3(&unknown_code).unwrap();
        assert_eq!(ack.rejected[0].error.code, ErrorCode::Internal);
        assert_eq!(ack.rejected[0].error.message, "hm");
    }
}
