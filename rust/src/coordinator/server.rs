//! TCP server: line-based request/response over a worker pool.
//!
//! Responses may span multiple lines and are terminated by one blank line.

use super::daemon::Daemon;
use super::threadpool::ThreadPool;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// The TCP front-end.
pub struct Server {
    listener: TcpListener,
    daemon: Arc<Daemon>,
    pool: ThreadPool,
}

impl Server {
    /// Bind to an address (use port 0 for an ephemeral port).
    pub fn bind(daemon: Arc<Daemon>, addr: &str, workers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        // Non-blocking accept so the loop can observe shutdown.
        listener.set_nonblocking(true).context("set_nonblocking")?;
        Ok(Self {
            listener,
            daemon,
            pool: ThreadPool::new(workers.max(1)),
        })
    }

    /// The bound address.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until the daemon shuts down.
    pub fn serve(&self) {
        while self.daemon.is_running() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let daemon = Arc::clone(&self.daemon);
                    self.pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &daemon) {
                            eprintln!("connection error: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }
}

fn handle_connection(stream: TcpStream, daemon: &Arc<Daemon>) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Short poll timeout so idle connections observe daemon shutdown
    // promptly (a long blocking read would stall worker-pool teardown).
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .context("read timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        // Note: on a poll timeout, any partially-read bytes stay in `line`
        // and the next read_line continues appending — no data loss.
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => {
                let trimmed = line.trim_end_matches(['\n', '\r']).to_string();
                line.clear();
                if trimmed.is_empty() {
                    continue;
                }
                let resp = daemon.handle_line(&trimmed);
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n\n")?;
                writer.flush()?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle poll tick: keep waiting unless shutting down.
            }
            Err(_) => break, // peer gone
        }
        if !daemon.is_running() {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::client::Client;
    use crate::coordinator::daemon::DaemonConfig;
    use crate::sched::SchedulerConfig;
    use crate::sim::SchedCosts;

    fn spawn_server() -> (Arc<Daemon>, SocketAddr, std::thread::JoinHandle<()>) {
        let daemon = Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            DaemonConfig {
                speedup: 10_000.0,
                pacer_tick_ms: 1,
            },
        );
        let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || server.serve());
        (daemon, addr, handle)
    }

    #[test]
    fn ping_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(c.request("PING").unwrap(), "OK pong");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn submit_and_squeue_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let resp = c.request("SUBMIT spot triple 320 9 600").unwrap();
        assert!(resp.starts_with("OK jobs="), "{resp}");
        let q = c.request("SQUEUE").unwrap();
        assert!(q.contains("triple-mode 320"), "{q}");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let (daemon, addr, handle) = spawn_server();
        let addr_s = addr.to_string();
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let a = addr_s.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    for _ in 0..10 {
                        assert_eq!(c.request("PING").unwrap(), "OK pong");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_over_tcp_stops_server() {
        let (_daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert!(c.request("SHUTDOWN").unwrap().starts_with("OK"));
        handle.join().unwrap(); // server loop must exit
    }
}
