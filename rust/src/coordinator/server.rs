//! TCP server: line-based request/response front door.
//!
//! Responses may span multiple lines and are terminated by one blank line.
//! Each connection starts in protocol v1 and may upgrade with `HELLO v2`;
//! the negotiated version is per-connection state. Requests on one
//! connection are answered strictly in order, so clients may **pipeline**
//! (write several request lines before reading the responses). A `HELLO
//! v3` upgrade switches the connection's byte stream to length-prefixed
//! binary frames (see `PROTOCOL.md`); both server paths speak the framed
//! dialect after the text ack.
//!
//! On **Linux** the server is an `epoll` reactor ([`super::reactor`]): the
//! listener and every connection are nonblocking and edge-triggered, idle
//! connections cost no thread and no poll tick, complete request lines are
//! dispatched to the small worker pool, and parked `WAIT`s resolve off the
//! daemon's completion hub through an eventfd. [`Server::bind_sharded`]
//! scales the front door out to N reactor **shards** on `SO_REUSEPORT`
//! listeners sharing one address: the kernel spreads accepts, each shard
//! thread owns its connections end to end (state machines, timer wheel,
//! wake eventfd, per-shard metrics), and the shards share only the worker
//! pool and the daemon. Other targets keep the portable threadpool server
//! below (always one shard): one pool worker drives each live connection,
//! blocked `WAIT`s detach into a waiter registry
//! ([`crate::coordinator::daemon::LineOutcome::Parked`]) so they never pin
//! workers, and a notifier thread resolves them.
//!
//! Accept errors on both paths back off exponentially (1 ms → 1 s ceiling,
//! reset on the next successful accept) and are counted in
//! [`DaemonMetrics::accept_errors`](super::metrics::DaemonMetrics) — a flat
//! 50 ms sleep used to hide persistent failures from the metrics entirely.

use super::daemon::Daemon;
use super::threadpool::ThreadPool;
use crate::util::error::{Context, Result};
use std::net::{SocketAddr, TcpListener};
#[cfg(target_os = "linux")]
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::Duration;

#[cfg(not(target_os = "linux"))]
use {
    super::api::{ApiError, ProtocolVersion, Response},
    super::codec,
    super::daemon::{LineOutcome, ParkedWait, TokenBucket},
    super::manifest::ChunkAssembler,
    std::io::{BufRead, BufReader, Read, Write},
    std::net::TcpStream,
    std::sync::atomic::Ordering,
    std::sync::Mutex,
    std::time::Instant,
};

/// Default idle-connection expiry.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// First accept-error backoff step (doubles per consecutive error).
#[cfg(not(target_os = "linux"))]
const ACCEPT_BACKOFF_START: Duration = Duration::from_millis(1);
/// Accept-error backoff ceiling.
#[cfg(not(target_os = "linux"))]
const ACCEPT_BACKOFF_CEILING: Duration = Duration::from_secs(1);

/// Longest the notifier thread sleeps between deadline sweeps (a
/// completion notify ends the sleep early).
#[cfg(not(target_os = "linux"))]
const WAITER_TICK: Duration = Duration::from_millis(20);

/// Cap on concurrently parked `WAIT`s. Detaching waits from the worker
/// pool removed the pool-size back-pressure; without a cap a client could
/// park an unbounded number of sockets for up to `MAX_WAIT_SECS` each.
/// Past the cap a `WAIT` fails fast with an `unsupported` error.
#[cfg(not(target_os = "linux"))]
const MAX_PARKED_WAITS: usize = 4096;

/// The TCP front-end.
pub struct Server {
    listener: TcpListener,
    daemon: Arc<Daemon>,
    pool: Arc<ThreadPool>,
    idle_timeout: Duration,
    /// Parked-`WAIT` gauge shard 0's reactor maintains.
    #[cfg(target_os = "linux")]
    parked_gauge: Arc<AtomicUsize>,
    /// Reactor shards beyond shard 0: each is an `SO_REUSEPORT` listener
    /// on the same address, served by its own reactor thread, with its own
    /// parked-`WAIT` gauge ([`Self::parked_waits`] sums them).
    #[cfg(target_os = "linux")]
    extra_shards: Vec<(TcpListener, Arc<AtomicUsize>)>,
    #[cfg(not(target_os = "linux"))]
    parked: Arc<ParkedWaits>,
}

impl Server {
    /// Bind to an address (use port 0 for an ephemeral port) with the
    /// default idle timeout and a single reactor shard. `workers` sizes
    /// the request-handling pool; on Linux connections themselves are
    /// multiplexed on the reactor thread(s), so the pool only bounds
    /// concurrently *executing* requests.
    pub fn bind(daemon: Arc<Daemon>, addr: &str, workers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        // Non-blocking accept so the serve loop can observe shutdown.
        listener.set_nonblocking(true).context("set_nonblocking")?;
        Ok(Self {
            listener,
            daemon,
            pool: Arc::new(ThreadPool::new(workers.max(1))),
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            #[cfg(target_os = "linux")]
            parked_gauge: Arc::new(AtomicUsize::new(0)),
            #[cfg(target_os = "linux")]
            extra_shards: Vec::new(),
            #[cfg(not(target_os = "linux"))]
            parked: Arc::new(ParkedWaits::default()),
        })
    }

    /// Bind `shards` reactor shards to one address. On Linux each shard is
    /// an `SO_REUSEPORT` listener (the kernel spreads accepts across them)
    /// served by its own reactor thread; a connection's whole lifetime
    /// stays on the shard that accepted it. Requires an IPv4 address
    /// literal (`host:port`). `shards <= 1` — and every non-Linux target,
    /// where the portable server has no reactor to shard — is exactly
    /// [`Server::bind`].
    pub fn bind_sharded(
        daemon: Arc<Daemon>,
        addr: &str,
        workers: usize,
        shards: usize,
    ) -> Result<Self> {
        #[cfg(target_os = "linux")]
        if shards > 1 {
            let sa: std::net::SocketAddrV4 = addr
                .parse()
                .with_context(|| format!("sharded bind needs an IPv4 addr literal, got {addr}"))?;
            let mut listeners = super::reactor::reuseport_listeners(sa, shards)
                .with_context(|| format!("binding {shards} SO_REUSEPORT shards on {addr}"))?;
            let listener = listeners.remove(0);
            return Ok(Self {
                listener,
                daemon,
                pool: Arc::new(ThreadPool::new(workers.max(1))),
                idle_timeout: DEFAULT_IDLE_TIMEOUT,
                parked_gauge: Arc::new(AtomicUsize::new(0)),
                extra_shards: listeners
                    .into_iter()
                    .map(|l| (l, Arc::new(AtomicUsize::new(0))))
                    .collect(),
            });
        }
        let _ = shards;
        Self::bind(daemon, addr, workers)
    }

    /// Builder: expire connections with no complete request for `d`.
    pub fn with_idle_timeout(mut self, d: Duration) -> Self {
        self.idle_timeout = d;
        self
    }

    /// The bound address.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// How many reactor shards will serve (1 unless [`Server::bind_sharded`]
    /// created more; always 1 on non-Linux targets).
    pub fn reactor_shards(&self) -> usize {
        #[cfg(target_os = "linux")]
        {
            1 + self.extra_shards.len()
        }
        #[cfg(not(target_os = "linux"))]
        {
            1
        }
    }

    /// Connections currently parked in a blocked `WAIT`, across all shards
    /// (tests/ops).
    pub fn parked_waits(&self) -> usize {
        #[cfg(target_os = "linux")]
        {
            use std::sync::atomic::Ordering;
            self.parked_gauge.load(Ordering::Relaxed)
                + self
                    .extra_shards
                    .iter()
                    .map(|(_, g)| g.load(Ordering::Relaxed))
                    .sum::<usize>()
        }
        #[cfg(not(target_os = "linux"))]
        {
            self.parked.len()
        }
    }

    /// Serve until the daemon shuts down. Shard 0 runs on the calling
    /// thread; extra shards (from [`Server::bind_sharded`]) each get their
    /// own thread, joined before this returns — shutdown therefore drains
    /// every shard (each reactor's completion-hub subscription wakes it to
    /// observe the stop, flush queued responses, and resolve parked
    /// `WAIT`s exactly once).
    #[cfg(target_os = "linux")]
    pub fn serve(&self) {
        let shard0 = self.daemon.metrics.register_reactor_shard();
        let extra_metrics: Vec<_> = self
            .extra_shards
            .iter()
            .map(|_| self.daemon.metrics.register_reactor_shard())
            .collect();
        std::thread::scope(|s| {
            for ((listener, gauge), shard) in self.extra_shards.iter().zip(&extra_metrics) {
                let daemon = &self.daemon;
                let pool = &self.pool;
                let idle = self.idle_timeout;
                std::thread::Builder::new()
                    .name(format!("spotcloud-reactor-{}", shard.index))
                    .spawn_scoped(s, move || {
                        super::reactor::serve(listener, daemon, pool, idle, gauge, shard)
                    })
                    .expect("spawning reactor shard");
            }
            super::reactor::serve(
                &self.listener,
                &self.daemon,
                &self.pool,
                self.idle_timeout,
                &self.parked_gauge,
                &shard0,
            );
        });
    }

    /// Serve until the daemon shuts down (portable threadpool path).
    #[cfg(not(target_os = "linux"))]
    pub fn serve(&self) {
        let waiter = self.spawn_waiter();
        let mut backoff = ACCEPT_BACKOFF_START;
        while self.daemon.is_running() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    backoff = ACCEPT_BACKOFF_START;
                    self.daemon
                        .metrics
                        .connections_accepted
                        .fetch_add(1, Ordering::Relaxed);
                    let ov = self.daemon.overload_config();
                    let bucket = if ov.conn_rate > 0.0 {
                        Some(TokenBucket::new(ov.conn_rate, ov.conn_burst, Instant::now()))
                    } else {
                        None
                    };
                    match Conn::new(stream, self.idle_timeout, bucket) {
                        Ok(conn) => {
                            let daemon = Arc::clone(&self.daemon);
                            let parked = Arc::clone(&self.parked);
                            self.pool.execute(move || drive_connection(conn, daemon, parked));
                        }
                        Err(e) => eprintln!("connection setup error: {e:#}"),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    // Count and back off exponentially: a persistent accept
                    // failure (EMFILE, …) should neither spin nor hide.
                    self.daemon.metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!("accept error: {e}");
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(ACCEPT_BACKOFF_CEILING);
                }
            }
        }
        let _ = waiter.join();
    }

    /// Spawn the waiter/notifier thread: resolves parked `WAIT`s on
    /// completion notifies and deadline sweeps, then recycles their
    /// connections into the worker pool.
    #[cfg(not(target_os = "linux"))]
    fn spawn_waiter(&self) -> std::thread::JoinHandle<()> {
        let daemon = Arc::clone(&self.daemon);
        let parked = Arc::clone(&self.parked);
        let pool = Arc::clone(&self.pool);
        std::thread::Builder::new()
            .name("spotcloud-waiter".into())
            .spawn(move || {
                while daemon.is_running() {
                    // Parked waits must make virtual-time progress even when
                    // no pacer thread runs (the old WAIT loop paced from the
                    // blocked request thread). With nothing parked there is
                    // nothing to advance for — don't duplicate the pacer.
                    if !parked.is_empty() {
                        daemon.pace();
                    }
                    // Read the generation *after* pacing so our own publish
                    // cannot spin the loop, but a concurrent one wakes it.
                    let gen = daemon.completion_generation();
                    for (mut session, resp) in parked.take_resolved(&daemon) {
                        let rendered = daemon.finish_wait(&session.wait, resp);
                        if session.conn.write_response(&rendered).is_ok() {
                            session.conn.last_activity = Instant::now();
                            let daemon = Arc::clone(&daemon);
                            let parked = Arc::clone(&parked);
                            pool.execute(move || drive_connection(session.conn, daemon, parked));
                        }
                    }
                    let timeout = parked
                        .nearest_deadline()
                        .map(|d| d.saturating_duration_since(Instant::now()))
                        .unwrap_or(WAITER_TICK)
                        .clamp(Duration::from_millis(1), WAITER_TICK);
                    daemon.wait_completion(gen, timeout);
                }
                // Shutdown: close the registry (a racing park now resolves
                // inline on its worker instead of landing in a registry no
                // one polls) and fail any still-parked waits so clients are
                // not left hanging on a dead socket.
                for (mut session, resp) in parked.close_and_resolve(&daemon) {
                    let rendered = daemon.finish_wait(&session.wait, resp);
                    let _ = session.conn.write_response(&rendered);
                }
            })
            .expect("spawning waiter")
    }
}

/// The registry of connections blocked in `WAIT`.
#[cfg(not(target_os = "linux"))]
#[derive(Default)]
struct ParkedWaits {
    inner: Mutex<ParkedInner>,
}

#[cfg(not(target_os = "linux"))]
#[derive(Default)]
struct ParkedInner {
    sessions: Vec<ParkedSession>,
    /// Set by the waiter thread on its way out: no one polls the registry
    /// anymore, so parks must resolve inline on their worker.
    closed: bool,
}

/// One parked connection: the socket state plus the wait it blocks on.
#[cfg(not(target_os = "linux"))]
struct ParkedSession {
    conn: Conn,
    wait: ParkedWait,
}

#[cfg(not(target_os = "linux"))]
impl ParkedWaits {
    fn len(&self) -> usize {
        self.inner
            .lock()
            .expect("parked registry poisoned")
            .sessions
            .len()
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Try to park; gives the session back when the registry is closed
    /// (shutdown raced the park) or full (back-pressure).
    fn push(&self, session: ParkedSession) -> std::result::Result<(), ParkedSession> {
        let mut inner = self.inner.lock().expect("parked registry poisoned");
        if inner.closed || inner.sessions.len() >= MAX_PARKED_WAITS {
            return Err(session);
        }
        inner.sessions.push(session);
        Ok(())
    }

    /// Remove and return every parked wait the daemon can answer now
    /// (settled, timed out, or shutting down), with its response.
    fn take_resolved(&self, daemon: &Daemon) -> Vec<(ParkedSession, Response)> {
        let mut inner = self.inner.lock().expect("parked registry poisoned");
        let mut resolved = Vec::new();
        let mut i = 0;
        while i < inner.sessions.len() {
            match daemon.poll_wait(&inner.sessions[i].wait.ticket) {
                Some(resp) => resolved.push((inner.sessions.swap_remove(i), resp)),
                None => i += 1,
            }
        }
        resolved
    }

    /// Earliest deadline among parked waits.
    fn nearest_deadline(&self) -> Option<Instant> {
        self.inner
            .lock()
            .expect("parked registry poisoned")
            .sessions
            .iter()
            .map(|s| s.wait.ticket.deadline)
            .min()
    }

    /// Close the registry and drain it, answering each wait one final time
    /// (`poll_wait` always resolves once the daemon stopped).
    fn close_and_resolve(&self, daemon: &Daemon) -> Vec<(ParkedSession, Response)> {
        let mut inner = self.inner.lock().expect("parked registry poisoned");
        inner.closed = true;
        inner
            .sessions
            .drain(..)
            .map(|s| {
                let resp = daemon
                    .poll_wait(&s.wait.ticket)
                    .unwrap_or_else(|| daemon.reject_wait(&s.wait.ticket, "daemon is shutting down"));
                (s, resp)
            })
            .collect()
    }
}

/// Per-connection socket state, detachable from its worker thread.
#[cfg(not(target_os = "linux"))]
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    version: ProtocolVersion,
    /// Chunked-`MSUBMIT` assembly state (v2.1); follows the connection when
    /// a parked `WAIT` detaches it from its worker.
    chunks: ChunkAssembler,
    line: String,
    /// Buffered unparsed bytes while the connection speaks v3 frames.
    frame_buf: Vec<u8>,
    idle_timeout: Duration,
    last_activity: Instant,
    accepted_at: Instant,
    first_byte_sent: bool,
    /// Per-connection request-line token bucket
    /// ([`super::daemon::OverloadConfig::conn_rate`]); `None` when the
    /// limit is disabled.
    bucket: Option<TokenBucket>,
}

/// Why a connection left its serve loop.
#[cfg(not(target_os = "linux"))]
enum ConnExit {
    /// Peer gone, idle-expired, or daemon stopped: drop the connection.
    Closed,
    /// A `WAIT` parked: move the connection into the waiter registry.
    Parked(ParkedWait),
}

#[cfg(not(target_os = "linux"))]
impl Conn {
    fn new(stream: TcpStream, idle_timeout: Duration, bucket: Option<TokenBucket>) -> Result<Self> {
        stream.set_nodelay(true).ok();
        // Short poll timeout so idle connections observe daemon shutdown
        // (and their own idle expiry) promptly — a long blocking read would
        // stall worker-pool teardown.
        stream
            .set_read_timeout(Some(Duration::from_millis(200)))
            .context("read timeout")?;
        let writer = stream.try_clone().context("cloning stream")?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
            // Every connection starts in v1; HELLO upgrades it.
            version: ProtocolVersion::V1,
            chunks: ChunkAssembler::new(),
            line: String::new(),
            frame_buf: Vec::new(),
            idle_timeout,
            last_activity: Instant::now(),
            accepted_at: Instant::now(),
            first_byte_sent: false,
            bucket,
        })
    }

    /// Serve requests until the peer closes, the connection idles out, the
    /// daemon stops, or a `WAIT` parks the connection.
    fn serve(&mut self, daemon: &Daemon) -> ConnExit {
        // A connection resuming after a parked `WAIT` may already have
        // upgraded to the framed dialect.
        if self.version.binary_frames() {
            return self.serve_frames(daemon);
        }
        loop {
            // Note: on a poll timeout, any partially-read bytes stay in
            // `self.line` and the next read_line continues appending — no
            // data loss.
            match self.reader.read_line(&mut self.line) {
                Ok(0) => return ConnExit::Closed, // peer closed
                Ok(_) => {
                    let arrived = Instant::now();
                    self.last_activity = arrived;
                    let trimmed = self.line.trim_end_matches(['\n', '\r']).to_string();
                    self.line.clear();
                    if trimmed.is_empty() {
                        continue;
                    }
                    // Per-connection rate limit: an over-rate line is
                    // refused before it reaches the daemon.
                    if let Some(bucket) = self.bucket.as_mut() {
                        if let Err(retry_ms) = bucket.try_take(arrived) {
                            daemon
                                .metrics
                                .shed_rate_limited
                                .fetch_add(1, Ordering::Relaxed);
                            let resp = codec::render_response(
                                &Response::Error(ApiError::overloaded(
                                    "connection request rate limit exceeded",
                                    retry_ms,
                                )),
                                self.version,
                            );
                            if self.write_response(&resp).is_err() {
                                return ConnExit::Closed; // peer gone
                            }
                            continue;
                        }
                    }
                    match daemon.handle_line_at(
                        &trimmed,
                        self.version,
                        Some(&mut self.chunks),
                        arrived,
                    ) {
                        LineOutcome::Done(resp, negotiated) => {
                            if let Some(v) = negotiated {
                                self.version = v;
                            }
                            // A HELLO v3 ack itself still goes out in text;
                            // only bytes after the upgrade are framed.
                            if self.write_text_response(&resp).is_err() {
                                return ConnExit::Closed; // peer gone
                            }
                            self.note_first_byte(daemon);
                            // Handling time must not count as idle.
                            self.last_activity = Instant::now();
                            if self.version.binary_frames() {
                                // HELLO v3 just landed: switch dialects.
                                return self.serve_frames(daemon);
                            }
                        }
                        LineOutcome::Parked(wait) => return ConnExit::Parked(wait),
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // Idle poll tick: expire silent connections so the
                    // worker goes back to serving the accept queue.
                    if self.last_activity.elapsed() >= self.idle_timeout {
                        return ConnExit::Closed;
                    }
                }
                Err(_) => return ConnExit::Closed, // peer gone
            }
            if !daemon.is_running() {
                return ConnExit::Closed;
            }
        }
    }

    /// Serve length-prefixed v3 frames until the peer closes, the
    /// connection idles out, the daemon stops, or a `WAIT` parks it.
    /// `OP_MSUBMIT` payloads are decoded straight from the buffered bytes
    /// ([`codec::parse_msubmit_v3`]) — no intermediate text line.
    fn serve_frames(&mut self, daemon: &Daemon) -> ConnExit {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            // Handle every complete frame already buffered.
            loop {
                let (opcode, payload_start, end) = match codec::decode_frame_header(&self.frame_buf)
                {
                    Err(e) => {
                        // The length prefix is garbage: everything after it
                        // is unframeable — answer typed and hang up.
                        let resp =
                            codec::render_response(&Response::Error(e), ProtocolVersion::V3);
                        let _ = self.write_frame(codec::OP_TEXT_RESP, resp.as_bytes());
                        return ConnExit::Closed;
                    }
                    Ok(None) => break,
                    Ok(Some(len)) => {
                        if self.frame_buf.len() < codec::FRAME_HEADER_BYTES + len {
                            break; // frame still in flight
                        }
                        let start = codec::FRAME_HEADER_BYTES;
                        (self.frame_buf[start], start + 1, start + len)
                    }
                };
                let arrived = Instant::now();
                self.last_activity = arrived;
                // The rate limit charges per frame, as the text path
                // charges per line.
                if let Some(bucket) = self.bucket.as_mut() {
                    if let Err(retry_ms) = bucket.try_take(arrived) {
                        daemon
                            .metrics
                            .shed_rate_limited
                            .fetch_add(1, Ordering::Relaxed);
                        let resp = codec::render_response(
                            &Response::Error(ApiError::overloaded(
                                "connection request rate limit exceeded",
                                retry_ms,
                            )),
                            ProtocolVersion::V3,
                        );
                        self.frame_buf.drain(..end);
                        if self.write_frame(codec::OP_TEXT_RESP, resp.as_bytes()).is_err() {
                            return ConnExit::Closed;
                        }
                        continue;
                    }
                }
                match opcode {
                    codec::OP_TEXT_REQ => {
                        let line = String::from_utf8_lossy(&self.frame_buf[payload_start..end])
                            .into_owned();
                        self.frame_buf.drain(..end);
                        let outcome = daemon.handle_line_at(
                            &line,
                            ProtocolVersion::V3,
                            Some(&mut self.chunks),
                            arrived,
                        );
                        match outcome {
                            LineOutcome::Done(resp, _) => {
                                if self.write_frame(codec::OP_TEXT_RESP, resp.as_bytes()).is_err()
                                {
                                    return ConnExit::Closed;
                                }
                                self.note_first_byte(daemon);
                                self.last_activity = Instant::now();
                            }
                            LineOutcome::Parked(wait) => return ConnExit::Parked(wait),
                        }
                    }
                    codec::OP_MSUBMIT => {
                        let parsed = codec::parse_msubmit_v3(&self.frame_buf[payload_start..end]);
                        self.frame_buf.drain(..end);
                        let frame = daemon.handle_msubmit_frame(parsed, Some(&mut self.chunks));
                        if self.write_raw(&frame).is_err() {
                            return ConnExit::Closed;
                        }
                        self.note_first_byte(daemon);
                        self.last_activity = Instant::now();
                    }
                    other => {
                        // Frame boundaries survive a bad opcode: typed
                        // error, keep serving.
                        self.frame_buf.drain(..end);
                        let resp = codec::render_response(
                            &Response::Error(ApiError::unsupported(format!(
                                "unknown v3 frame opcode {other:#04x}"
                            ))),
                            ProtocolVersion::V3,
                        );
                        if self.write_frame(codec::OP_TEXT_RESP, resp.as_bytes()).is_err() {
                            return ConnExit::Closed;
                        }
                    }
                }
                if !daemon.is_running() {
                    return ConnExit::Closed;
                }
            }
            if !daemon.is_running() {
                return ConnExit::Closed;
            }
            // Read more bytes; the 200 ms poll timeout doubles as the
            // idle/shutdown tick, exactly like the text loop.
            match self.reader.read(&mut chunk) {
                Ok(0) => return ConnExit::Closed, // peer closed
                Ok(n) => {
                    self.frame_buf.extend_from_slice(&chunk[..n]);
                    self.last_activity = Instant::now();
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if self.last_activity.elapsed() >= self.idle_timeout {
                        return ConnExit::Closed;
                    }
                }
                Err(_) => return ConnExit::Closed, // peer gone
            }
        }
    }

    /// Write a response in the connection's wire dialect — framed after a
    /// v3 upgrade, blank-line-terminated text before. The waiter thread
    /// resolves parked `WAIT`s through this, so a framed connection's wait
    /// answers arrive framed too.
    fn write_response(&mut self, resp: &str) -> std::io::Result<()> {
        if self.version.binary_frames() {
            return self.write_frame(codec::OP_TEXT_RESP, resp.as_bytes());
        }
        self.write_text_response(resp)
    }

    fn write_text_response(&mut self, resp: &str) -> std::io::Result<()> {
        self.writer.write_all(resp.as_bytes())?;
        self.writer.write_all(b"\n\n")?;
        self.writer.flush()
    }

    fn write_frame(&mut self, opcode: u8, payload: &[u8]) -> std::io::Result<()> {
        self.write_raw(&codec::v3_frame(opcode, payload))
    }

    fn write_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.writer.write_all(bytes)?;
        self.writer.flush()
    }

    fn note_first_byte(&mut self, daemon: &Daemon) {
        if !self.first_byte_sent {
            self.first_byte_sent = true;
            daemon
                .metrics
                .record_accept_to_first_byte(self.accepted_at.elapsed().as_nanos() as u64);
        }
    }
}

/// Run a connection's serve loop on a pool worker; a parked `WAIT` hands
/// the connection to the waiter registry and frees the worker.
#[cfg(not(target_os = "linux"))]
fn drive_connection(mut conn: Conn, daemon: Arc<Daemon>, parked: Arc<ParkedWaits>) {
    loop {
        match conn.serve(&daemon) {
            ConnExit::Closed => return,
            ConnExit::Parked(wait) => match parked.push(ParkedSession { conn, wait }) {
                Ok(()) => {
                    // Wake the waiter thread so it re-computes the nearest
                    // deadline.
                    daemon.kick_waiters();
                    return;
                }
                Err(mut session) => {
                    // Registry closed (shutdown raced the park) or full:
                    // resolve inline on this worker — exactly once, like any
                    // other wait — then keep serving the connection.
                    let resp = daemon.poll_wait(&session.wait.ticket).unwrap_or_else(|| {
                        daemon.reject_wait(&session.wait.ticket, "too many concurrent WAITs")
                    });
                    let rendered = daemon.finish_wait(&session.wait, resp);
                    if session.conn.write_response(&rendered).is_err() || !daemon.is_running() {
                        return;
                    }
                    session.conn.last_activity = Instant::now();
                    conn = session.conn;
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::api::{ProtocolVersion, Request, Response, SqueueFilter, SubmitSpec};
    use crate::coordinator::client::Client;
    use crate::coordinator::daemon::DaemonConfig;
    use crate::job::{JobType, QosClass};
    use crate::sched::SchedulerConfig;
    use crate::sim::SchedCosts;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Instant;

    fn spawn_server() -> (Arc<Daemon>, SocketAddr, std::thread::JoinHandle<()>) {
        spawn_server_with(DEFAULT_IDLE_TIMEOUT, 2, 4096)
    }

    fn test_daemon(user_limit: u32) -> Arc<Daemon> {
        Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
                .with_user_limit(user_limit),
            DaemonConfig {
                speedup: 10_000.0,
                pacer_tick_ms: 1,
                // Keep retirement out of the server tests (wall-timing
                // coupling at high speedup).
                retire_grace_secs: Some(86_400.0),
                ..DaemonConfig::default()
            },
        )
    }

    fn spawn_server_with(
        idle: Duration,
        workers: usize,
        user_limit: u32,
    ) -> (Arc<Daemon>, SocketAddr, std::thread::JoinHandle<()>) {
        let daemon = test_daemon(user_limit);
        let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", workers)
            .unwrap()
            .with_idle_timeout(idle);
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || server.serve());
        (daemon, addr, handle)
    }

    /// Read one blank-line-terminated response from a raw socket.
    fn read_raw_response(reader: &mut BufReader<TcpStream>) -> String {
        let mut out = String::new();
        loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).expect("read response");
            assert!(n > 0, "server closed mid-response (got {out:?})");
            if line == "\n" {
                break;
            }
            out.push_str(&line);
        }
        out.trim_end_matches('\n').to_string()
    }

    #[test]
    fn ping_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(c.request("PING").unwrap(), "OK pong");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn submit_and_squeue_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let resp = c.request("SUBMIT spot triple 320 9 600").unwrap();
        assert!(resp.starts_with("OK jobs="), "{resp}");
        let q = c.request("SQUEUE").unwrap();
        assert!(q.contains("triple-mode 320"), "{q}");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn typed_v2_session_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect_v2(&addr.to_string()).unwrap();
        let ack = c
            .submit(&SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 320, 9))
            .unwrap();
        assert_eq!(ack.count, 1);
        let rows = c.squeue(&SqueueFilter::default()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].tasks, 320);
        let util = c.util().unwrap();
        assert_eq!(util.total_cores, 608);
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let (daemon, addr, handle) = spawn_server();
        let addr_s = addr.to_string();
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let a = addr_s.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    for _ in 0..10 {
                        assert_eq!(c.request("PING").unwrap(), "OK pong");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn idle_connection_is_recycled() {
        let (daemon, addr, handle) = spawn_server_with(Duration::from_millis(300), 2, 4096);
        let mut idle = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(idle.request("PING").unwrap(), "OK pong");
        // Go silent past the idle timeout: the server must close us.
        std::thread::sleep(Duration::from_millis(900));
        assert!(idle.request("PING").is_err(), "idle connection must expire");
        // A fresh connection is served fine afterwards.
        let mut fresh = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(fresh.request("PING").unwrap(), "OK pong");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn parked_waits_do_not_pin_workers() {
        // A 2-worker pool holds 4 concurrent blocked WAITs *and* keeps
        // serving: blocked waits park off the pool instead of pinning
        // workers. The waited-on job exceeds the 100-core user limit, so
        // only the timeout can resolve the waits.
        let (daemon, addr, handle) = spawn_server_with(DEFAULT_IDLE_TIMEOUT, 2, 100);
        let addr_s = addr.to_string();
        // Scope the submitter so its (idle) connection does not occupy a
        // worker for the rest of the test.
        let ack = {
            let mut submitter = Client::connect_v2(&addr_s).unwrap();
            submitter
                .submit(
                    &SubmitSpec::new(QosClass::Normal, JobType::Array, 200, 1).with_run_secs(60.0),
                )
                .unwrap()
        };
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let a = addr_s.clone();
                let id = ack.first;
                std::thread::spawn(move || {
                    let mut c = Client::connect_v2(&a).unwrap();
                    let w = c.wait(&[id], 3.0).unwrap();
                    // The connection keeps serving after its wait resumes.
                    let util = c.util().unwrap();
                    assert_eq!(util.total_cores, 608);
                    w
                })
            })
            .collect();
        // Give the waits time to park, then prove the pool still serves
        // (probe scoped too: resumed connections need the workers back).
        std::thread::sleep(Duration::from_millis(500));
        let t0 = Instant::now();
        {
            let mut probe = Client::connect(&addr_s).unwrap();
            assert_eq!(probe.request("PING").unwrap(), "OK pong");
        }
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "blocked WAITs pinned the worker pool"
        );
        for t in waiters {
            let w = t.join().unwrap();
            assert!(w.timed_out, "{w:?}");
            assert_eq!(w.dispatched, 0);
        }
        // Exactly-once: every parked wait resolved exactly once.
        use std::sync::atomic::Ordering;
        assert_eq!(
            daemon.metrics.waits_parked.load(Ordering::Relaxed),
            daemon.metrics.waits_resumed.load(Ordering::Relaxed)
        );
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn parked_wait_wakes_on_terminal_progress() {
        // A WAIT on a job that can never dispatch resolves as soon as the
        // job is cancelled — the completion notify, not the timeout.
        let (daemon, addr, handle) = spawn_server_with(DEFAULT_IDLE_TIMEOUT, 2, 100);
        let addr_s = addr.to_string();
        let mut submitter = Client::connect_v2(&addr_s).unwrap();
        let ack = submitter
            .submit(&SubmitSpec::new(QosClass::Normal, JobType::Array, 200, 1).with_run_secs(60.0))
            .unwrap();
        let waiter = {
            let a = addr_s.clone();
            let id = ack.first;
            std::thread::spawn(move || {
                let mut c = Client::connect_v2(&a).unwrap();
                let t0 = Instant::now();
                let w = c.wait(&[id], 30.0).unwrap();
                (w, t0.elapsed())
            })
        };
        std::thread::sleep(Duration::from_millis(300));
        submitter.cancel(ack.first).unwrap();
        let (w, waited) = waiter.join().unwrap();
        assert!(!w.timed_out, "{w:?}");
        assert_eq!(w.dispatched, 0);
        assert!(
            waited < Duration::from_secs(10),
            "cancel did not wake the parked wait ({waited:?})"
        );
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_over_tcp_stops_server() {
        let (_daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert!(c.request("SHUTDOWN").unwrap().starts_with("OK"));
        handle.join().unwrap(); // server loop must exit
    }

    #[test]
    fn slow_loris_partial_lines_parse_exactly_once() {
        // One byte per write, with pauses that force the bytes across
        // separate readiness events: the partially-read line must stay
        // buffered and yield exactly one parsed request — never a spliced
        // or dropped line.
        let (daemon, addr, handle) = spawn_server();
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        for b in b"PING\n" {
            writer.write_all(&[*b]).unwrap();
            writer.flush().unwrap();
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(read_raw_response(&mut reader), "OK pong");
        // Now two requests spliced across odd chunk boundaries.
        for chunk in [b"PI".as_slice(), b"NG\nPI".as_slice(), b"NG\n".as_slice()] {
            writer.write_all(chunk).unwrap();
            writer.flush().unwrap();
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(read_raw_response(&mut reader), "OK pong");
        assert_eq!(read_raw_response(&mut reader), "OK pong");
        // Exactly three PINGs parsed — no splice, no drop, no duplicate.
        let pings = daemon
            .metrics
            .command_counts()
            .into_iter()
            .find(|(cmd, _)| *cmd == "PING")
            .map(|(_, n)| n)
            .unwrap();
        assert_eq!(pings, 3);
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn pipelined_requests_answer_in_order() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect_v2(&addr.to_string()).unwrap();
        let resps = c
            .pipeline(&[Request::Ping, Request::Util, Request::Ping])
            .unwrap();
        assert_eq!(resps.len(), 3);
        assert_eq!(resps[0], Response::Pong);
        assert!(matches!(&resps[1], Response::Util(u) if u.total_cores == 608));
        assert_eq!(resps[2], Response::Pong);
        // The connection keeps serving normal round trips afterwards.
        c.ping().unwrap();
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn chunked_msubmit_streams_a_manifest_over_tcp() {
        use crate::coordinator::manifest::ManifestBuilder;
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect_v21(&addr.to_string()).unwrap();
        let mut b = ManifestBuilder::new();
        for u in 0..25 {
            b = b.interactive(u % 5, JobType::Array, 1);
        }
        // 25 entries in chunks of 10: parts 1 and 2 draw chunk acks, part 3
        // admits the whole manifest atomically.
        let ack = c.msubmit_chunked(&b.build(), 10).unwrap();
        assert_eq!(ack.accepted.len(), 25);
        assert_eq!(ack.jobs, 25);
        assert!(ack.rejected.is_empty(), "{:?}", ack.rejected);
        let first = ack.accepted.first().unwrap().first;
        let last = ack.accepted.last().unwrap().last;
        assert_eq!(last - first + 1, 25, "one contiguous id range across parts");
        // The connection keeps serving after the stream completes.
        c.ping().unwrap();
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn interrupting_a_chunked_stream_discards_the_partial_manifest() {
        let (daemon, addr, handle) = spawn_server();
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writer.write_all(b"HELLO v2.1\n").unwrap();
        writer.flush().unwrap();
        assert_eq!(read_raw_response(&mut reader), "OK kind=hello proto=v2.1");
        writer
            .write_all(b"MSUBMIT entries=2 part=1/2;qos=normal type=array tasks=1 user=7\n")
            .unwrap();
        writer.flush().unwrap();
        assert_eq!(
            read_raw_response(&mut reader),
            "OK kind=chunk_ack part=1 parts=2 received=1"
        );
        // A different command mid-stream: typed error, partial discarded,
        // and the interrupting request is NOT executed.
        writer.write_all(b"PING\n").unwrap();
        writer.flush().unwrap();
        let resp = read_raw_response(&mut reader);
        assert!(resp.starts_with("ERR"), "{resp}");
        assert!(resp.contains("discarded"), "{resp}");
        {
            let mut probe = Client::connect_v2(&addr.to_string()).unwrap();
            assert!(
                probe.squeue(&SqueueFilter::default()).unwrap().is_empty(),
                "no partial manifest may be admitted"
            );
        }
        // The same connection restarts the stream from part 1.
        writer
            .write_all(b"MSUBMIT entries=2 part=1/2;qos=normal type=array tasks=1 user=7\n")
            .unwrap();
        writer
            .write_all(b"MSUBMIT entries=2 part=2/2;qos=normal type=array tasks=1 user=7\n")
            .unwrap();
        writer.flush().unwrap();
        assert_eq!(
            read_raw_response(&mut reader),
            "OK kind=chunk_ack part=1 parts=2 received=1"
        );
        let fin = read_raw_response(&mut reader);
        assert!(fin.starts_with("OK kind=manifest_ack"), "{fin}");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn v3_binary_session_over_tcp() {
        use crate::coordinator::manifest::ManifestBuilder;
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect_v3(&addr.to_string()).unwrap();
        assert_eq!(c.version(), ProtocolVersion::V3);
        // Framed text round trips.
        c.ping().unwrap();
        let util = c.util().unwrap();
        assert_eq!(util.total_cores, 608);
        // Binary manifest submission: varint-packed out, packed ack back.
        let mut b = ManifestBuilder::new();
        for u in 0..25 {
            b = b.interactive(u % 5, JobType::Array, 1);
        }
        let ack = c.msubmit(&b.build()).unwrap();
        assert_eq!(ack.accepted.len(), 25);
        assert_eq!(ack.jobs, 25);
        assert!(ack.rejected.is_empty(), "{:?}", ack.rejected);
        // The session keeps serving typed round trips after the binary
        // exchange — framing stayed in sync.
        let rows = c.squeue(&SqueueFilter::default()).unwrap();
        assert_eq!(rows.len(), 25);
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn chunked_msubmit_requires_v21_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect_v2(&addr.to_string()).unwrap();
        let resp = c
            .request("MSUBMIT entries=2 part=1/2;qos=normal type=array tasks=1 user=7")
            .unwrap();
        assert!(resp.starts_with("ERR"), "{resp}");
        assert!(resp.contains("v2.1"), "{resp}");
        daemon.shutdown();
        handle.join().unwrap();
    }

    /// The sharded front door: two `SO_REUSEPORT` reactor shards serve one
    /// address, their counter blocks register per shard, and shutdown
    /// joins (drains) every shard thread.
    #[cfg(target_os = "linux")]
    #[test]
    fn sharded_server_serves_and_drains_all_shards() {
        use std::sync::atomic::Ordering;
        let daemon = test_daemon(4096);
        let server = Server::bind_sharded(Arc::clone(&daemon), "127.0.0.1:0", 4, 2).unwrap();
        assert_eq!(server.reactor_shards(), 2);
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || server.serve());
        let addr_s = addr.to_string();
        // Distinct source ports, so the kernel's REUSEPORT hash spreads
        // connections; every one must be served wherever it lands.
        let mut clients: Vec<Client> =
            (0..16).map(|_| Client::connect(&addr_s).unwrap()).collect();
        for c in &mut clients {
            assert_eq!(c.request("PING").unwrap(), "OK pong");
        }
        let shards = daemon.metrics.reactor_shards();
        assert_eq!(shards.len(), 2, "one counter block per reactor shard");
        let accepted: u64 = shards.iter().map(|s| s.accepted.load(Ordering::Relaxed)).sum();
        assert_eq!(accepted, 16, "every accept attributed to a shard");
        drop(clients);
        daemon.shutdown();
        handle.join().unwrap(); // joins shard threads => all shards drained
    }

    /// Pacing for parked WAITs runs on the worker pool, not the reactor
    /// thread (ROADMAP: a loaded scheduler pass used to stall I/O for the
    /// pace duration) — and I/O stays served while a wait is parked.
    #[cfg(target_os = "linux")]
    #[test]
    fn parked_wait_pacing_is_offloaded_to_the_worker_pool() {
        use std::sync::atomic::Ordering;
        let (daemon, addr, handle) = spawn_server_with(DEFAULT_IDLE_TIMEOUT, 2, 100);
        let addr_s = addr.to_string();
        let ack = {
            let mut submitter = Client::connect_v2(&addr_s).unwrap();
            // Over the 100-core user limit: can only resolve by timeout.
            submitter
                .submit(
                    &SubmitSpec::new(QosClass::Normal, JobType::Array, 200, 1).with_run_secs(60.0),
                )
                .unwrap()
        };
        let waiter = {
            let a = addr_s.clone();
            let id = ack.first;
            std::thread::spawn(move || {
                let mut c = Client::connect_v2(&a).unwrap();
                c.wait(&[id], 2.0).unwrap()
            })
        };
        // While the wait is parked, pacing must be happening (virtual time
        // advances for it) and the reactor must keep serving requests.
        std::thread::sleep(Duration::from_millis(500));
        assert!(
            daemon.metrics.pace_offloads.load(Ordering::Relaxed) > 0,
            "no pace was offloaded while a WAIT was parked"
        );
        let mut probe = Client::connect(&addr_s).unwrap();
        assert_eq!(probe.request("PING").unwrap(), "OK pong");
        let w = waiter.join().unwrap();
        assert!(w.timed_out, "{w:?}");
        daemon.shutdown();
        handle.join().unwrap();
    }

    /// The reactor's zero-poll guarantee at test scale: established idle
    /// connections produce no reactor wakeups at all.
    #[cfg(target_os = "linux")]
    #[test]
    fn idle_connections_do_not_wake_the_reactor() {
        use std::sync::atomic::Ordering;
        let (daemon, addr, handle) = spawn_server();
        let addr_s = addr.to_string();
        let mut idle: Vec<Client> = (0..3).map(|_| Client::connect(&addr_s).unwrap()).collect();
        for c in &mut idle {
            assert_eq!(c.request("PING").unwrap(), "OK pong");
        }
        // Let the last completions drain, then watch the wakeup counter.
        std::thread::sleep(Duration::from_millis(150));
        let w0 = daemon.metrics.reactor_wakeups.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(400));
        let delta = daemon.metrics.reactor_wakeups.load(Ordering::Relaxed) - w0;
        assert!(delta <= 2, "idle connections woke the reactor {delta} times");
        daemon.shutdown();
        handle.join().unwrap();
    }
}
