//! TCP server: line-based request/response over a worker pool.
//!
//! Responses may span multiple lines and are terminated by one blank line.
//! Each connection starts in protocol v1 and may upgrade with `HELLO v2`;
//! the negotiated version is per-connection state held here. Idle
//! connections are expired after [`Server::idle_timeout`] so a silent client
//! cannot pin a worker thread forever.

use super::api::ProtocolVersion;
use super::daemon::Daemon;
use super::threadpool::ThreadPool;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default idle-connection expiry.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// The TCP front-end.
pub struct Server {
    listener: TcpListener,
    daemon: Arc<Daemon>,
    pool: ThreadPool,
    idle_timeout: Duration,
}

impl Server {
    /// Bind to an address (use port 0 for an ephemeral port) with the
    /// default idle timeout.
    pub fn bind(daemon: Arc<Daemon>, addr: &str, workers: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        // Non-blocking accept so the loop can observe shutdown.
        listener.set_nonblocking(true).context("set_nonblocking")?;
        Ok(Self {
            listener,
            daemon,
            pool: ThreadPool::new(workers.max(1)),
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
        })
    }

    /// Builder: expire connections with no complete request for `d`,
    /// recycling their worker back into the pool.
    pub fn with_idle_timeout(mut self, d: Duration) -> Self {
        self.idle_timeout = d;
        self
    }

    /// The bound address.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until the daemon shuts down.
    pub fn serve(&self) {
        while self.daemon.is_running() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let daemon = Arc::clone(&self.daemon);
                    let idle_timeout = self.idle_timeout;
                    self.pool.execute(move || {
                        if let Err(e) = handle_connection(stream, &daemon, idle_timeout) {
                            eprintln!("connection error: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }
}

fn handle_connection(stream: TcpStream, daemon: &Arc<Daemon>, idle_timeout: Duration) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Short poll timeout so idle connections observe daemon shutdown (and
    // their own idle expiry) promptly — a long blocking read would stall
    // worker-pool teardown.
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .context("read timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // Every connection starts in v1; HELLO upgrades it.
    let mut version = ProtocolVersion::V1;
    let mut last_activity = Instant::now();
    loop {
        // Note: on a poll timeout, any partially-read bytes stay in `line`
        // and the next read_line continues appending — no data loss.
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => {
                last_activity = Instant::now();
                let trimmed = line.trim_end_matches(['\n', '\r']).to_string();
                line.clear();
                if trimmed.is_empty() {
                    continue;
                }
                let (resp, negotiated) = daemon.handle_line_versioned(&trimmed, version);
                if let Some(v) = negotiated {
                    version = v;
                }
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n\n")?;
                writer.flush()?;
                // Handling time (e.g. a long WAIT) must not count as idle.
                last_activity = Instant::now();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle poll tick: expire silent connections so the worker
                // goes back to serving the accept queue.
                if last_activity.elapsed() >= idle_timeout {
                    break;
                }
            }
            Err(_) => break, // peer gone
        }
        if !daemon.is_running() {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::api::{SqueueFilter, SubmitSpec};
    use crate::coordinator::client::Client;
    use crate::coordinator::daemon::DaemonConfig;
    use crate::job::{JobType, QosClass};
    use crate::sched::SchedulerConfig;
    use crate::sim::SchedCosts;

    fn spawn_server() -> (Arc<Daemon>, SocketAddr, std::thread::JoinHandle<()>) {
        spawn_server_with(DEFAULT_IDLE_TIMEOUT)
    }

    fn spawn_server_with(
        idle: Duration,
    ) -> (Arc<Daemon>, SocketAddr, std::thread::JoinHandle<()>) {
        let daemon = Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            DaemonConfig {
                speedup: 10_000.0,
                pacer_tick_ms: 1,
            },
        );
        let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2)
            .unwrap()
            .with_idle_timeout(idle);
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || server.serve());
        (daemon, addr, handle)
    }

    #[test]
    fn ping_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(c.request("PING").unwrap(), "OK pong");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn submit_and_squeue_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let resp = c.request("SUBMIT spot triple 320 9 600").unwrap();
        assert!(resp.starts_with("OK jobs="), "{resp}");
        let q = c.request("SQUEUE").unwrap();
        assert!(q.contains("triple-mode 320"), "{q}");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn typed_v2_session_over_tcp() {
        let (daemon, addr, handle) = spawn_server();
        let mut c = Client::connect_v2(&addr.to_string()).unwrap();
        let ack = c
            .submit(&SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 320, 9))
            .unwrap();
        assert_eq!(ack.count, 1);
        let rows = c.squeue(&SqueueFilter::default()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].tasks, 320);
        let util = c.util().unwrap();
        assert_eq!(util.total_cores, 608);
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let (daemon, addr, handle) = spawn_server();
        let addr_s = addr.to_string();
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let a = addr_s.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    for _ in 0..10 {
                        assert_eq!(c.request("PING").unwrap(), "OK pong");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn idle_connection_is_recycled() {
        let (daemon, addr, handle) = spawn_server_with(Duration::from_millis(300));
        let mut idle = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(idle.request("PING").unwrap(), "OK pong");
        // Go silent past the idle timeout: the server must close us.
        std::thread::sleep(Duration::from_millis(900));
        assert!(idle.request("PING").is_err(), "idle connection must expire");
        // The recycled worker serves a fresh connection fine.
        let mut fresh = Client::connect(&addr.to_string()).unwrap();
        assert_eq!(fresh.request("PING").unwrap(), "OK pong");
        daemon.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_over_tcp_stops_server() {
        let (_daemon, addr, handle) = spawn_server();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert!(c.request("SHUTDOWN").unwrap().starts_with("OK"));
        handle.join().unwrap(); // server loop must exit
    }
}
