//! Submission manifests: heterogeneous batch submission as one typed value.
//!
//! The legacy `SUBMIT ... count=N` body can only clone one homogeneous spec,
//! yet every paper-shaped workload is a *mixture* — interactive and spot,
//! individual/array/triple-mode, several users. A [`Manifest`] carries a
//! list of per-entry job specs (user, QoS, launch type, tasks, cores per
//! task, run time, tag, per-entry repeat count) so a whole heterogeneous
//! burst lands in **one** RPC and one scheduler lock
//! ([`crate::sched::Scheduler::submit_batch`]).
//!
//! Admission is **partial-accept**: each entry is validated independently;
//! invalid entries come back as typed [`EntryReject`]s while every valid
//! entry is admitted atomically (all accepted jobs reach the controller at
//! the same virtual instant). Wire-level malformation (a record that does
//! not parse) rejects the whole request instead — see
//! [`super::codec`] and `PROTOCOL.md` §MSUBMIT for the grammar.
//!
//! [`ManifestBuilder`] is the client-side construction API used by the CLI,
//! the workload generators ([`crate::workload::manifests`]), and the live
//! Figure-2 experiments.

use super::api::ApiError;
use crate::job::{JobSpec, JobType, QosClass, UserId};
use crate::sim::SimTime;
use std::fmt;
use std::sync::Arc;
use std::time::Instant;

/// Cap on entries in one manifest — bounds wire body and admission work
/// per RPC while staying above the paper's 10k-entry workloads. Sized so a
/// maximal legal line (each record at the codec's 256-byte cap, plus
/// separators) stays near 3 MB, comfortably under the server's 4 MB
/// per-connection buffered-request cap: a protocol-legal manifest must
/// always get a typed response, never a buffer-overflow connection close.
pub const MAX_MANIFEST_ENTRIES: usize = 12_000;

/// Cap on tag length (bytes).
pub const MAX_TAG_LEN: usize = 64;

/// Cap on the *declared* entry total of a chunked (v2.1) `MSUBMIT` stream.
/// Each part still obeys the per-line limits (so a part can carry at most
/// [`MAX_MANIFEST_ENTRIES`]-ish records), but the assembled manifest may be
/// far larger than one line allows. The cap bounds per-connection assembler
/// memory: at ~100 bytes per buffered entry the worst case stays in the
/// tens of megabytes, and the daemon's aggregate
/// [`super::daemon::MAX_BATCH_JOBS`] job cap still applies at admission.
pub const MAX_CHUNKED_MANIFEST_ENTRIES: usize = 250_000;

/// Cap on parts in one chunked stream (desync and slow-loris bound; with
/// non-empty parts this is also a floor on per-part progress).
pub const MAX_CHUNK_PARTS: u32 = 1024;

/// Is `tag` a legal manifest tag? One token of `[A-Za-z0-9._:/-]`, 1 to
/// [`MAX_TAG_LEN`] bytes — whitespace-free and record-separator-free by
/// construction, so tags can never desync the wire.
pub fn tag_is_valid(tag: &str) -> bool {
    !tag.is_empty()
        && tag.len() <= MAX_TAG_LEN
        && tag
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b':' | b'/' | b'-'))
}

/// One manifest entry: a job spec plus a per-entry repeat count.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    /// Submitting user id.
    pub user: u32,
    /// QoS class.
    pub qos: QosClass,
    /// Launch type.
    pub job_type: JobType,
    /// Tasks per job.
    pub tasks: u32,
    /// Cores per task (1 throughout the paper's experiments).
    pub cores_per_task: u32,
    /// Per-job run time in virtual seconds.
    pub run_secs: f64,
    /// Copies of this entry to submit.
    pub count: u32,
    /// Optional tag carried through the job table to `SQUEUE`/`SJOB`.
    pub tag: Option<Arc<str>>,
}

impl ManifestEntry {
    /// A single-copy entry with the default one-hour run time.
    pub fn new(qos: QosClass, job_type: JobType, tasks: u32, user: u32) -> Self {
        Self {
            user,
            qos,
            job_type,
            tasks,
            cores_per_task: 1,
            run_secs: 3600.0,
            count: 1,
            tag: None,
        }
    }

    /// Builder: per-job run time (virtual seconds).
    pub fn with_run_secs(mut self, run_secs: f64) -> Self {
        self.run_secs = run_secs;
        self
    }

    /// Builder: per-entry repeat count.
    pub fn with_count(mut self, count: u32) -> Self {
        self.count = count;
        self
    }

    /// Builder: cores per task.
    pub fn with_cores_per_task(mut self, cores: u32) -> Self {
        self.cores_per_task = cores;
        self
    }

    /// Builder: tag.
    pub fn with_tag(mut self, tag: impl Into<Arc<str>>) -> Self {
        self.tag = Some(tag.into());
        self
    }

    /// Jobs this entry materializes: `count` copies of the paper's
    /// per-type expansion (an interactive *individual* submission of `T`
    /// tasks is `T` separate one-task jobs; everything else is one job).
    pub fn jobs(&self) -> u64 {
        let per_copy = match (self.qos, self.job_type) {
            (QosClass::Normal, JobType::Individual) => self.tasks as u64,
            _ => 1,
        };
        self.count as u64 * per_copy
    }

    /// Semantic validation (degenerate shapes land here as typed errors,
    /// not as silently-unschedulable jobs; wire-level malformation is the
    /// codec's problem).
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.tasks == 0 {
            return Err(ApiError::bad_arg("tasks", "0"));
        }
        if self.count == 0 {
            return Err(ApiError::bad_arg("count", "0"));
        }
        if self.cores_per_task == 0 {
            return Err(ApiError::bad_arg("cores_per_task", "0"));
        }
        if !(self.run_secs.is_finite() && self.run_secs >= 0.0) {
            return Err(ApiError::bad_arg("run_secs", &self.run_secs.to_string()));
        }
        if let Some(tag) = &self.tag {
            if !tag_is_valid(tag) {
                return Err(ApiError::bad_arg("tag", tag));
            }
        }
        if self.jobs() > super::daemon::MAX_BATCH_JOBS {
            return Err(ApiError::bad_arg(
                "count",
                &format!(
                    "{} (entry materializes more than {} jobs)",
                    self.count,
                    super::daemon::MAX_BATCH_JOBS
                ),
            ));
        }
        Ok(())
    }

    /// Materialize the entry's job specs, in submission order.
    pub fn materialize(&self) -> Vec<JobSpec> {
        let run = SimTime::from_secs_f64(self.run_secs);
        let mut out = Vec::with_capacity(self.jobs() as usize);
        for _ in 0..self.count {
            match (self.qos, self.job_type) {
                (QosClass::Normal, JobType::Individual) => {
                    for _ in 0..self.tasks {
                        out.push(self.spec_of(1, run));
                    }
                }
                _ => out.push(self.spec_of(self.tasks, run)),
            }
        }
        out
    }

    fn spec_of(&self, tasks: u32, run: SimTime) -> JobSpec {
        let base = match self.qos {
            QosClass::Normal => JobSpec::interactive(UserId(self.user), self.job_type, tasks),
            QosClass::Spot => JobSpec::spot(UserId(self.user), self.job_type, tasks),
        };
        let spec = base.with_run_time(run).with_cores_per_task(self.cores_per_task);
        match &self.tag {
            // One shared allocation per entry, however many jobs it expands to.
            Some(tag) => spec.with_tag(Arc::clone(tag)),
            None => spec,
        }
    }
}

/// A typed submission manifest: an ordered list of entries.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Manifest {
    /// The entries, in submission order (per-entry acks index into this).
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Total jobs the manifest materializes (before validation).
    pub fn jobs(&self) -> u64 {
        self.entries.iter().map(ManifestEntry::jobs).sum()
    }

    /// The first entry whose tag cannot be framed on the wire (fails
    /// [`tag_is_valid`]), as `(index, tag)`. A typed builder can hold any
    /// string; rendering one with whitespace, `;`, or a newline would
    /// corrupt the record framing (or inject a second request line), so
    /// the client refuses to send such a manifest — the server never sees
    /// an unframeable tag from a well-behaved client, and a hostile one
    /// is caught by the codec/admission checks.
    pub fn first_unframeable_tag(&self) -> Option<(usize, &str)> {
        self.entries.iter().enumerate().find_map(|(i, e)| {
            e.tag
                .as_deref()
                .filter(|t| !tag_is_valid(t))
                .map(|t| (i, t))
        })
    }
}

/// Client-side manifest construction.
#[derive(Debug, Clone, Default)]
pub struct ManifestBuilder {
    entries: Vec<ManifestEntry>,
}

impl ManifestBuilder {
    /// An empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a fully-specified entry.
    pub fn entry(mut self, entry: ManifestEntry) -> Self {
        self.entries.push(entry);
        self
    }

    /// Append an interactive (Normal QoS) entry.
    pub fn interactive(self, user: u32, job_type: JobType, tasks: u32) -> Self {
        self.entry(ManifestEntry::new(QosClass::Normal, job_type, tasks, user))
    }

    /// Append a spot entry.
    pub fn spot(self, user: u32, job_type: JobType, tasks: u32) -> Self {
        self.entry(ManifestEntry::new(QosClass::Spot, job_type, tasks, user))
    }

    /// Modify the most recently added entry (builder-style per-entry knobs).
    pub fn last(mut self, f: impl FnOnce(ManifestEntry) -> ManifestEntry) -> Self {
        if let Some(e) = self.entries.pop() {
            self.entries.push(f(e));
        }
        self
    }

    /// Entries added so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// No entries yet?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Finish.
    pub fn build(self) -> Manifest {
        Manifest {
            entries: self.entries,
        }
    }
}

/// One part of a streaming (chunked) v2.1 `MSUBMIT` body.
///
/// The wire form is `MSUBMIT entries=<n> part=<i>/<k>;<record>;...` — the
/// client declares the manifest's total entry count up front, then streams
/// the entries across `k` consecutive request lines on one connection. The
/// declaration is repeated on every part so a desynchronized stream is
/// detected at the first mismatched part, not at the end.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestChunk {
    /// Total entries the client declared for the whole manifest.
    pub entries: u32,
    /// This part's index, 1-based.
    pub part: u32,
    /// Total parts the stream will carry.
    pub parts: u32,
    /// The entries carried by this part, in manifest order.
    pub records: Vec<ManifestEntry>,
}

/// Outcome of feeding one part to a [`ChunkAssembler`].
#[derive(Debug, Clone, PartialEq)]
pub enum ChunkOutcome {
    /// Intermediate part buffered — answer with `Response::ChunkAck`.
    Partial {
        /// The part just received (1-based).
        part: u32,
        /// Total parts the client declared.
        parts: u32,
        /// Entries buffered so far across the received parts.
        received: u64,
    },
    /// Final part received: the fully assembled manifest, ready for the
    /// normal `MSUBMIT` admission path (with the chunked entry cap).
    Complete(Manifest),
}

#[derive(Debug)]
struct Assembling {
    declared: u32,
    parts: u32,
    next_part: u32,
    entries: Vec<ManifestEntry>,
}

/// Per-connection assembler for chunked `MSUBMIT` bodies.
///
/// Strictly sequential: parts must arrive as `1..=k` with identical
/// `entries=` and `/k` declarations and no other verb in between. Any
/// violation **discards the partial manifest** and returns a typed error —
/// the stream cannot resume mid-way, the client restarts from part 1. The
/// transport owns one assembler per connection ([`super::server`] /
/// `reactor`); the daemon itself stays connection-state-free.
#[derive(Debug, Default)]
pub struct ChunkAssembler {
    state: Option<Assembling>,
    /// The tightest `deadline_ms=` budget seen across the stream's parts
    /// (a deadline on any part binds the whole manifest — the final part's
    /// admission checks it before taking a scheduler lock).
    deadline: Option<Instant>,
}

impl ChunkAssembler {
    /// An idle assembler.
    pub fn new() -> Self {
        Self::default()
    }

    /// Is a chunked stream mid-assembly on this connection?
    pub fn in_progress(&self) -> bool {
        self.state.is_some()
    }

    /// Entries buffered so far (0 when idle).
    pub fn received(&self) -> u64 {
        self.state.as_ref().map_or(0, |a| a.entries.len() as u64)
    }

    /// Tighten the stream's deadline budget (min across parts).
    pub fn note_deadline(&mut self, at: Instant) {
        self.deadline = Some(match self.deadline {
            Some(cur) => cur.min(at),
            None => at,
        });
    }

    /// The stream's effective deadline, if any part carried one.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Drop the deadline budget (stream completed, errored, or aborted).
    pub fn clear_deadline(&mut self) {
        self.deadline = None;
    }

    /// Discard any partial stream (connection close, or an interrupting
    /// verb). Returns `true` if a stream was actually in progress, so the
    /// transport can surface a typed error for the abandoned body.
    pub fn abort(&mut self) -> bool {
        self.deadline = None;
        self.state.take().is_some()
    }

    /// Feed one part. On success returns [`ChunkOutcome::Partial`] (reply
    /// `ChunkAck`) or [`ChunkOutcome::Complete`] (admit the manifest). On
    /// any error the partial stream is discarded and the assembler is idle
    /// again — errors are never resumable mid-stream.
    pub fn push(&mut self, chunk: ManifestChunk) -> Result<ChunkOutcome, ApiError> {
        if let Err(e) = Self::validate_shape(&chunk) {
            self.state = None;
            return Err(e);
        }
        let mut cur = match self.state.take() {
            None => {
                if chunk.part != 1 {
                    return Err(ApiError::bad_arg(
                        "part",
                        &format!("{}/{} (no stream in progress; expected part 1)", chunk.part, chunk.parts),
                    ));
                }
                Assembling {
                    declared: chunk.entries,
                    parts: chunk.parts,
                    next_part: 1,
                    entries: Vec::with_capacity((chunk.entries as usize).min(MAX_CHUNKED_MANIFEST_ENTRIES)),
                }
            }
            Some(cur) => {
                if chunk.part != cur.next_part || chunk.parts != cur.parts || chunk.entries != cur.declared {
                    return Err(ApiError::bad_arg(
                        "part",
                        &format!(
                            "entries={} part={}/{} (stream expected entries={} part={}/{}; partial manifest discarded)",
                            chunk.entries, chunk.part, chunk.parts, cur.declared, cur.next_part, cur.parts
                        ),
                    ));
                }
                cur
            }
        };
        cur.entries.extend(chunk.records);
        if cur.entries.len() as u64 > u64::from(cur.declared) {
            return Err(ApiError::bad_arg(
                "entries",
                &format!("{} received, {} declared (partial manifest discarded)", cur.entries.len(), cur.declared),
            ));
        }
        if chunk.part == cur.parts {
            if cur.entries.len() as u64 != u64::from(cur.declared) {
                return Err(ApiError::bad_arg(
                    "entries",
                    &format!("final part closed the stream at {} entries, {} declared", cur.entries.len(), cur.declared),
                ));
            }
            return Ok(ChunkOutcome::Complete(Manifest { entries: cur.entries }));
        }
        cur.next_part = chunk.part + 1;
        let out = ChunkOutcome::Partial {
            part: chunk.part,
            parts: cur.parts,
            received: cur.entries.len() as u64,
        };
        self.state = Some(cur);
        Ok(out)
    }

    fn validate_shape(chunk: &ManifestChunk) -> Result<(), ApiError> {
        if chunk.parts == 0 || chunk.parts > MAX_CHUNK_PARTS {
            return Err(ApiError::bad_arg("parts", &chunk.parts.to_string()));
        }
        if chunk.part == 0 || chunk.part > chunk.parts {
            return Err(ApiError::bad_arg(
                "part",
                &format!("{}/{}", chunk.part, chunk.parts),
            ));
        }
        if chunk.entries == 0 || chunk.entries as usize > MAX_CHUNKED_MANIFEST_ENTRIES {
            return Err(ApiError::bad_arg("entries", &chunk.entries.to_string()));
        }
        if chunk.records.is_empty() {
            return Err(ApiError::bad_arg("records", "empty part"));
        }
        Ok(())
    }
}

/// One accepted entry: the contiguous job-id range the scheduler assigned
/// to it (entries are admitted in order under one lock, so each entry's
/// materialized jobs get consecutive ids).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EntryAck {
    /// Index into [`Manifest::entries`].
    pub index: u32,
    /// First assigned job id.
    pub first: u64,
    /// Last assigned job id.
    pub last: u64,
    /// Jobs created for this entry.
    pub count: u64,
}

impl EntryAck {
    /// The entry's assigned job ids.
    pub fn ids(&self) -> impl Iterator<Item = u64> {
        self.first..=self.last
    }
}

/// One rejected entry: its index plus the typed validation error. The rest
/// of the manifest is unaffected (partial accept).
#[derive(Debug, Clone, PartialEq)]
pub struct EntryReject {
    /// Index into [`Manifest::entries`].
    pub index: u32,
    /// Why admission refused it.
    pub error: ApiError,
}

/// The manifest submission outcome: per-entry acks and rejects.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ManifestAck {
    /// Accepted entries, ascending index order.
    pub accepted: Vec<EntryAck>,
    /// Rejected entries, ascending index order.
    pub rejected: Vec<EntryReject>,
    /// Total jobs created.
    pub jobs: u64,
    /// The daemon-assigned manifest id, used by `RESUME` and the
    /// `WAIT manifest=<id> entry=<k>` form. `None` when talking to a
    /// pre-durability peer that does not assign ids (or when every entry
    /// was rejected, so there is nothing to resume).
    pub manifest: Option<u64>,
}

impl ManifestAck {
    /// Every assigned job id, in submission order. (The capacity hint is
    /// clamped: `jobs` is wire data, and a hand-built or hostile value
    /// must not drive a giant allocation — the codec additionally rejects
    /// acks whose records do not sum to `jobs`.)
    pub fn job_ids(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity((self.jobs as usize).min(1 << 20));
        for a in &self.accepted {
            out.extend(a.ids());
        }
        out
    }

    /// The ack for one manifest entry index, if it was accepted.
    pub fn entry(&self, index: u32) -> Option<&EntryAck> {
        self.accepted.iter().find(|a| a.index == index)
    }
}

impl fmt::Display for ManifestAck {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "accepted={} rejected={} jobs={}",
            self.accepted.len(),
            self.rejected.len(),
            self.jobs
        )
    }
}

/// One accepted entry as the daemon remembers it: the contiguous id span
/// plus the client-visible tag. This is the minimal state `RESUME` and
/// `WAIT manifest= entry=` need, so it is what the registry keeps and what
/// the durability checkpoint persists.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestSpan {
    /// Index into the original manifest's entry list.
    pub index: u32,
    /// First assigned job id.
    pub first: u64,
    /// Jobs in the span.
    pub count: u64,
    /// The entry's tag, if any.
    pub tag: Option<Arc<str>>,
}

impl ManifestSpan {
    /// Job ids covered by this span.
    pub fn ids(&self) -> impl Iterator<Item = u64> {
        self.first..self.first + self.count
    }
}

/// One registered manifest: its id and accepted-entry spans.
#[derive(Debug, Clone, PartialEq)]
pub struct RegisteredManifest {
    /// Daemon-assigned manifest id (monotonic, starts at 1).
    pub id: u64,
    /// Accepted entries, ascending index order. Rejected entries leave no
    /// span — resume only ever sees work that was actually admitted.
    pub spans: Vec<ManifestSpan>,
    /// The submission tag the whole manifest is findable under (the tag of
    /// its first tagged entry), if any.
    pub tag: Option<Arc<str>>,
}

/// The daemon's manifest registry: manifest id → accepted spans, plus a
/// tag → latest-manifest index for `RESUME tag=`. Registered atomically
/// with admission (under the scheduler lock) and rebuilt verbatim from the
/// durability checkpoint + journal tail on recovery.
#[derive(Debug)]
pub struct ManifestRegistry {
    manifests: std::collections::BTreeMap<u64, RegisteredManifest>,
    by_tag: std::collections::HashMap<Arc<str>, u64>,
    next_id: u64,
}

impl Default for ManifestRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ManifestRegistry {
    /// An empty registry; ids start at 1.
    pub fn new() -> Self {
        Self {
            manifests: std::collections::BTreeMap::new(),
            by_tag: std::collections::HashMap::new(),
            next_id: 1,
        }
    }

    /// The id the next registered manifest will get (persisted in
    /// checkpoints so recovery never reuses an id).
    pub fn next_id(&self) -> u64 {
        self.next_id
    }

    /// Register a freshly admitted manifest; returns its assigned id, or
    /// `None` if no entry was accepted (nothing to resume). Every tag in
    /// the manifest points at this id afterwards — "latest manifest wins"
    /// is the resume-by-tag contract.
    pub fn register(&mut self, spans: Vec<ManifestSpan>) -> Option<u64> {
        if spans.is_empty() {
            return None;
        }
        let id = self.next_id;
        self.insert(id, spans);
        self.next_id += 1;
        Some(id)
    }

    /// Re-insert a manifest with a known id during crash recovery.
    /// Advances `next_id` past it; later re-registrations of the same tag
    /// overwrite the tag index exactly as live registration does.
    pub fn restore(&mut self, id: u64, spans: Vec<ManifestSpan>) {
        self.insert(id, spans);
        self.next_id = self.next_id.max(id + 1);
    }

    /// Force the id counter (from a checkpoint) — `max`, never backwards.
    pub fn force_next_id(&mut self, next: u64) {
        self.next_id = self.next_id.max(next);
    }

    /// Restore a manifest only if no manifest with this id is present —
    /// `true` if it was inserted. Sharded recovery uses this to merge
    /// per-shard checkpoints and tail replays: the checkpoint with the
    /// newest registry (highest `global_seq`) restores first and stays
    /// authoritative; parts replayed from other shard journals only fill
    /// ids it had not yet captured.
    pub fn restore_if_absent(&mut self, id: u64, spans: Vec<ManifestSpan>) -> bool {
        if self.manifests.contains_key(&id) {
            self.next_id = self.next_id.max(id + 1);
            return false;
        }
        self.restore(id, spans);
        true
    }

    fn insert(&mut self, id: u64, spans: Vec<ManifestSpan>) {
        debug_assert!(!spans.is_empty());
        let tag = spans.iter().find_map(|s| s.tag.clone());
        for span in &spans {
            if let Some(t) = &span.tag {
                self.by_tag.insert(Arc::clone(t), id);
            }
        }
        self.manifests.insert(id, RegisteredManifest { id, spans, tag });
    }

    /// Look up a manifest by id.
    pub fn get(&self, id: u64) -> Option<&RegisteredManifest> {
        self.manifests.get(&id)
    }

    /// Look up the **latest** manifest registered under `tag`.
    pub fn by_tag(&self, tag: &str) -> Option<&RegisteredManifest> {
        self.by_tag.get(tag).and_then(|id| self.manifests.get(id))
    }

    /// The id span for one entry of one manifest.
    pub fn span(&self, manifest: u64, entry: u32) -> Option<&ManifestSpan> {
        self.get(manifest)
            .and_then(|m| m.spans.iter().find(|s| s.index == entry))
    }

    /// Registered manifests, ascending id order (checkpoint capture).
    pub fn iter(&self) -> impl Iterator<Item = &RegisteredManifest> {
        self.manifests.values()
    }

    /// Number of registered manifests.
    pub fn len(&self) -> usize {
        self.manifests.len()
    }

    /// No manifests registered?
    pub fn is_empty(&self) -> bool {
        self.manifests.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_expansion_matches_paper_model() {
        let ind = ManifestEntry::new(QosClass::Normal, JobType::Individual, 8, 1).with_count(3);
        assert_eq!(ind.jobs(), 24);
        let specs = ind.materialize();
        assert_eq!(specs.len(), 24);
        assert!(specs.iter().all(|s| s.tasks == 1));

        let arr = ManifestEntry::new(QosClass::Normal, JobType::Array, 8, 1).with_count(3);
        assert_eq!(arr.jobs(), 3);
        assert_eq!(arr.materialize().len(), 3);

        // Spot individual stays one job of `tasks` tasks (the legacy
        // SUBMIT expansion rule, kept bit-for-bit).
        let spot = ManifestEntry::new(QosClass::Spot, JobType::Individual, 8, 9);
        assert_eq!(spot.jobs(), 1);
        assert_eq!(spot.materialize()[0].tasks, 8);
    }

    #[test]
    fn materialized_specs_carry_tag_and_cores() {
        let e = ManifestEntry::new(QosClass::Normal, JobType::Individual, 4, 7)
            .with_cores_per_task(2)
            .with_run_secs(60.0)
            .with_tag("fig2-live");
        let specs = e.materialize();
        assert_eq!(specs.len(), 4);
        for s in &specs {
            assert_eq!(&*s.tag, "fig2-live");
            assert_eq!(s.cores_per_task, 2);
            assert_eq!(s.run_time, SimTime::from_secs(60));
        }
        // All four jobs share ONE tag allocation.
        assert!(Arc::ptr_eq(&specs[0].tag, &specs[3].tag));
    }

    #[test]
    fn degenerate_entries_fail_validation_with_typed_errors() {
        use crate::coordinator::api::ErrorCode;
        let base = || ManifestEntry::new(QosClass::Normal, JobType::Array, 4, 1);
        for (entry, what) in [
            (ManifestEntry { tasks: 0, ..base() }, "tasks"),
            (ManifestEntry { count: 0, ..base() }, "count"),
            (
                ManifestEntry {
                    cores_per_task: 0,
                    ..base()
                },
                "cores_per_task",
            ),
            (
                ManifestEntry {
                    run_secs: f64::NAN,
                    ..base()
                },
                "run_secs",
            ),
            (
                ManifestEntry {
                    run_secs: -1.0,
                    ..base()
                },
                "run_secs",
            ),
        ] {
            let err = entry.validate().expect_err(what);
            assert_eq!(err.code, ErrorCode::BadArg, "{what}: {err}");
            assert!(err.message.contains(what), "{what}: {err}");
        }
        assert!(base().validate().is_ok());
    }

    #[test]
    fn tag_charset_is_enforced() {
        assert!(tag_is_valid("fig2-live"));
        assert!(tag_is_valid("a.b:c/d_e-9"));
        assert!(!tag_is_valid(""));
        assert!(!tag_is_valid("has space"));
        assert!(!tag_is_valid("semi;colon"));
        assert!(!tag_is_valid("new\nline"));
        assert!(!tag_is_valid(&"x".repeat(MAX_TAG_LEN + 1)));
        let e = ManifestEntry::new(QosClass::Spot, JobType::TripleMode, 8, 1).with_tag("bad tag");
        assert!(e.validate().is_err());
    }

    #[test]
    fn unframeable_tags_are_detected_before_the_wire() {
        let ok = ManifestBuilder::new()
            .spot(9, JobType::Array, 8)
            .last(|e| e.with_tag("fine-tag"))
            .build();
        assert_eq!(ok.first_unframeable_tag(), None);
        let bad = ManifestBuilder::new()
            .interactive(1, JobType::Array, 8)
            .spot(9, JobType::Array, 8)
            .last(|e| e.with_tag("evil\nSHUTDOWN"))
            .build();
        assert_eq!(bad.first_unframeable_tag(), Some((1, "evil\nSHUTDOWN")));
    }

    #[test]
    fn builder_collects_heterogeneous_entries() {
        let m = ManifestBuilder::new()
            .interactive(1, JobType::TripleMode, 608)
            .last(|e| e.with_run_secs(120.0).with_tag("burst"))
            .spot(9, JobType::Array, 64)
            .last(|e| e.with_count(4))
            .interactive(2, JobType::Individual, 16)
            .build();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.entries[0].run_secs, 120.0);
        assert_eq!(m.entries[1].count, 4);
        assert_eq!(m.jobs(), 1 + 4 + 16);
    }

    #[test]
    fn ack_exposes_per_entry_id_ranges() {
        let ack = ManifestAck {
            accepted: vec![
                EntryAck {
                    index: 0,
                    first: 1,
                    last: 3,
                    count: 3,
                },
                EntryAck {
                    index: 2,
                    first: 4,
                    last: 4,
                    count: 1,
                },
            ],
            rejected: vec![EntryReject {
                index: 1,
                error: ApiError::bad_arg("tasks", "0"),
            }],
            jobs: 4,
            manifest: Some(7),
        };
        assert_eq!(ack.job_ids(), vec![1, 2, 3, 4]);
        assert_eq!(ack.entry(2).unwrap().first, 4);
        assert!(ack.entry(1).is_none());
        assert_eq!(ack.to_string(), "accepted=2 rejected=1 jobs=4");
    }

    fn span(index: u32, first: u64, count: u64, tag: Option<&str>) -> ManifestSpan {
        ManifestSpan {
            index,
            first,
            count,
            tag: tag.map(Arc::from),
        }
    }

    #[test]
    fn registry_assigns_monotonic_ids_and_latest_tag_wins() {
        let mut reg = ManifestRegistry::new();
        assert!(reg.register(vec![]).is_none(), "all-rejected manifest gets no id");
        let a = reg.register(vec![span(0, 1, 4, Some("burst"))]).unwrap();
        let b = reg
            .register(vec![span(0, 5, 2, None), span(1, 7, 1, Some("burst"))])
            .unwrap();
        assert_eq!((a, b), (1, 2));
        assert_eq!(reg.len(), 2);
        // Latest registration of "burst" wins.
        assert_eq!(reg.by_tag("burst").unwrap().id, b);
        assert!(reg.by_tag("missing").is_none());
        // Per-entry span lookup.
        assert_eq!(reg.span(b, 1).unwrap().first, 7);
        assert!(reg.span(b, 9).is_none());
        assert!(reg.span(99, 0).is_none());
        assert_eq!(reg.span(a, 0).unwrap().ids().collect::<Vec<_>>(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn registry_restore_replays_to_identical_state() {
        let mut live = ManifestRegistry::new();
        live.register(vec![span(0, 1, 3, Some("t1"))]);
        live.register(vec![span(0, 4, 2, Some("t1")), span(1, 6, 1, Some("t2"))]);

        let mut rebuilt = ManifestRegistry::new();
        for m in live.iter() {
            rebuilt.restore(m.id, m.spans.clone());
        }
        assert_eq!(rebuilt.next_id(), live.next_id());
        assert_eq!(rebuilt.by_tag("t1").unwrap().id, 2);
        assert_eq!(rebuilt.by_tag("t2").unwrap().id, 2);
        assert_eq!(rebuilt.get(1).unwrap().spans, live.get(1).unwrap().spans);
        // New registrations after restore continue the sequence.
        let next = rebuilt.register(vec![span(0, 7, 1, None)]).unwrap();
        assert_eq!(next, 3);
    }

    fn chunk(entries: u32, part: u32, parts: u32, users: &[u32]) -> ManifestChunk {
        ManifestChunk {
            entries,
            part,
            parts,
            records: users
                .iter()
                .map(|&u| ManifestEntry::new(QosClass::Normal, JobType::Array, 4, u))
                .collect(),
        }
    }

    #[test]
    fn assembler_streams_in_order_parts_into_one_manifest() {
        let mut asm = ChunkAssembler::new();
        assert!(!asm.in_progress());
        assert_eq!(
            asm.push(chunk(5, 1, 3, &[1, 2])).unwrap(),
            ChunkOutcome::Partial { part: 1, parts: 3, received: 2 }
        );
        assert!(asm.in_progress());
        assert_eq!(asm.received(), 2);
        assert_eq!(
            asm.push(chunk(5, 2, 3, &[3, 4])).unwrap(),
            ChunkOutcome::Partial { part: 2, parts: 3, received: 4 }
        );
        let ChunkOutcome::Complete(m) = asm.push(chunk(5, 3, 3, &[5])).unwrap() else {
            panic!("final part must complete the stream");
        };
        // Entry order is manifest order across parts.
        assert_eq!(m.entries.iter().map(|e| e.user).collect::<Vec<_>>(), vec![1, 2, 3, 4, 5]);
        assert!(!asm.in_progress(), "assembler idle after completion");
        assert_eq!(asm.received(), 0);
    }

    #[test]
    fn single_part_stream_completes_immediately() {
        let mut asm = ChunkAssembler::new();
        let ChunkOutcome::Complete(m) = asm.push(chunk(2, 1, 1, &[7, 8])).unwrap() else {
            panic!("1/1 part must complete");
        };
        assert_eq!(m.entries.len(), 2);
        assert!(!asm.in_progress());
    }

    #[test]
    fn desynchronized_streams_discard_and_error() {
        use crate::coordinator::api::ErrorCode;
        // Starting mid-stream.
        let mut asm = ChunkAssembler::new();
        let err = asm.push(chunk(4, 2, 2, &[1])).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadArg);
        assert!(!asm.in_progress());

        // Mismatched declaration mid-stream discards the partial body.
        asm.push(chunk(4, 1, 2, &[1, 2])).unwrap();
        let err = asm.push(chunk(9, 2, 2, &[3, 4])).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadArg);
        assert!(err.message.contains("discarded"), "{err}");
        assert!(!asm.in_progress(), "partial manifest discarded on desync");

        // Repeated part is a desync too.
        asm.push(chunk(4, 1, 2, &[1, 2])).unwrap();
        assert!(asm.push(chunk(4, 1, 2, &[1, 2])).is_err());
        assert!(!asm.in_progress());

        // A fresh part 1 after an error starts cleanly.
        asm.push(chunk(2, 1, 2, &[1])).unwrap();
        assert!(matches!(
            asm.push(chunk(2, 2, 2, &[2])).unwrap(),
            ChunkOutcome::Complete(_)
        ));
    }

    #[test]
    fn assembler_enforces_shape_and_count_caps() {
        use crate::coordinator::api::ErrorCode;
        let mut asm = ChunkAssembler::new();
        for bad in [
            chunk(0, 1, 2, &[1]),                                   // zero declared
            chunk(MAX_CHUNKED_MANIFEST_ENTRIES as u32 + 1, 1, 2, &[1]), // over cap
            chunk(4, 0, 2, &[1]),                                   // part 0
            chunk(4, 3, 2, &[1]),                                   // part > parts
            chunk(4, 1, 0, &[1]),                                   // zero parts
            chunk(4, 1, MAX_CHUNK_PARTS + 1, &[1]),                 // parts over cap
            chunk(4, 1, 2, &[]),                                    // empty part
        ] {
            let err = asm.push(bad).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadArg);
            assert!(!asm.in_progress());
        }

        // Overflowing the declaration discards the stream.
        asm.push(chunk(2, 1, 3, &[1, 2])).unwrap();
        assert!(asm.push(chunk(2, 2, 3, &[3])).is_err());
        assert!(!asm.in_progress());

        // Closing short of the declaration is an error.
        asm.push(chunk(5, 1, 2, &[1, 2])).unwrap();
        let err = asm.push(chunk(5, 2, 2, &[3])).unwrap_err();
        assert!(err.message.contains("5 declared"), "{err}");
        assert!(!asm.in_progress());
    }

    #[test]
    fn abort_discards_partial_state() {
        let mut asm = ChunkAssembler::new();
        assert!(!asm.abort(), "idle abort is a no-op");
        asm.push(chunk(4, 1, 2, &[1, 2])).unwrap();
        assert!(asm.abort(), "abort reports an in-progress stream");
        assert!(!asm.in_progress());
        assert_eq!(asm.received(), 0);
    }
}
