//! Crash recovery: rebuild the scheduler, manifest registry, and history
//! table from a recovered journal (checkpoint + tail replay).
//!
//! The journal stores *inputs*, not scheduler state: an [`Admit`] record
//! carries the manifest entries and the id range the scheduler assigned,
//! and replay re-materializes and re-submits them in the original order at
//! the original virtual time. The scheduler's id assignment is
//! deterministic, so the replayed range must equal the journaled one — any
//! divergence is a [`RecoveryError::Mismatch`], never a silent re-numbering
//! (acked ids are a client-visible contract).
//!
//! Jobs that were Running/Suspended at the checkpoint are restored as
//! Pending and re-queued at the checkpoint's virtual time: the simulated
//! cluster's in-flight placements died with the process, exactly like
//! requeue-on-preemption, but their pre-crash event-log entries (and so
//! their first-recognized/dispatch facts) are preserved for `SJOB`/`WAIT`.
//!
//! [`Admit`]: JournalRecord::Admit

use super::journal::{CheckpointState, JournalError, JournalRecord, RecoveredJournal};
use super::manifest::{ManifestRegistry, ManifestSpan};
use super::snapshot::JobView;
use crate::cluster::Cluster;
use crate::job::{JobId, JobState};
use crate::sched::{Scheduler, SchedulerConfig};
use std::fmt;

/// Why recovery failed.
#[derive(Debug)]
pub enum RecoveryError {
    /// The journal itself could not be read (I/O or unrecoverable
    /// corruption).
    Journal(JournalError),
    /// Replay diverged from the journaled facts (e.g. the re-admitted id
    /// range differs from the acked one).
    Mismatch(String),
}

impl fmt::Display for RecoveryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecoveryError::Journal(e) => write!(f, "journal: {e}"),
            RecoveryError::Mismatch(what) => write!(f, "replay mismatch: {what}"),
        }
    }
}

impl std::error::Error for RecoveryError {}

impl From<JournalError> for RecoveryError {
    fn from(e: JournalError) -> Self {
        RecoveryError::Journal(e)
    }
}

/// What recovery did, typed — the daemon logs it and tests assert on it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryReport {
    /// Whole newer segments discarded (torn mid-checkpoint rotation).
    pub segments_discarded: usize,
    /// Torn-tail bytes truncated from the surviving segment.
    pub torn_bytes: u64,
    /// Tail records replayed after the checkpoint.
    pub records_replayed: usize,
    /// Of those, admissions.
    pub admits_replayed: usize,
    /// Of those, cancellations.
    pub cancels_replayed: usize,
    /// Live jobs restored from the checkpoint.
    pub jobs_restored: usize,
    /// Checkpoint jobs that were Pending at capture.
    pub restored_pending: usize,
    /// Checkpoint jobs that were Running at capture (re-queued).
    pub restored_running: usize,
    /// Checkpoint jobs that were Requeued at capture.
    pub restored_requeued: usize,
    /// Checkpoint jobs that were Suspended at capture (re-queued).
    pub restored_suspended: usize,
    /// Retired-history views restored.
    pub history_restored: usize,
    /// Manifests restored (checkpoint + tail).
    pub manifests_restored: usize,
    /// Virtual time after replay (seconds).
    pub recovered_vtime_secs: f64,
    /// The scheduler's next job id after replay.
    pub next_id: u64,
}

impl fmt::Display for RecoveryReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "recovered vtime={:.3}s jobs={} (pending={} running={} requeued={} suspended={}) \
             history={} manifests={} replayed={} (admits={} cancels={}) torn_bytes={} \
             segments_discarded={} next_id={}",
            self.recovered_vtime_secs,
            self.jobs_restored,
            self.restored_pending,
            self.restored_running,
            self.restored_requeued,
            self.restored_suspended,
            self.history_restored,
            self.manifests_restored,
            self.records_replayed,
            self.admits_replayed,
            self.cancels_replayed,
            self.torn_bytes,
            self.segments_discarded,
            self.next_id,
        )
    }
}

/// Everything [`rebuild`] hands back for the daemon to adopt.
pub struct RebuiltState {
    /// The replayed scheduler, advanced to the last journaled instant.
    pub sched: Scheduler,
    /// The manifest registry (checkpoint manifests + tail admissions).
    pub registry: ManifestRegistry,
    /// Retired-history views, original retirement order (the daemon
    /// re-inserts them through its capped table so pruning semantics
    /// match a never-crashed daemon).
    pub history: Vec<JobView>,
    /// The typed report.
    pub report: RecoveryReport,
}

/// Rebuild scheduler + registry + history from a recovered journal over a
/// fresh cluster. `cluster`/`sched_cfg` must match the crashed daemon's —
/// the journal records inputs, not topology.
pub fn rebuild(
    cluster: Cluster,
    sched_cfg: SchedulerConfig,
    recovered: &RecoveredJournal,
) -> Result<RebuiltState, RecoveryError> {
    let cp = &recovered.checkpoint;
    let mut report = RecoveryReport {
        segments_discarded: recovered.segments_discarded,
        torn_bytes: recovered.torn_bytes,
        records_replayed: recovered.tail.len(),
        ..RecoveryReport::default()
    };

    let mut sched = Scheduler::new(cluster, sched_cfg);
    let mut registry = ManifestRegistry::new();
    restore_checkpoint(&mut sched, &mut registry, cp, &mut report);

    for rec in &recovered.tail {
        match rec {
            JournalRecord::Admit {
                vtime,
                first_id,
                total_jobs,
                manifest,
                entries,
            } => {
                report.admits_replayed += 1;
                if *vtime > sched.now() {
                    sched.run_until(*vtime);
                }
                // Re-materialize in admission order; the scheduler's
                // deterministic id assignment reproduces the acked range.
                let mut specs = Vec::new();
                let mut spans: Vec<ManifestSpan> = Vec::with_capacity(entries.len());
                for ae in entries {
                    let batch = ae.entry.materialize();
                    spans.push(ManifestSpan {
                        index: ae.index,
                        first: first_id + specs.len() as u64,
                        count: batch.len() as u64,
                        tag: ae.entry.tag.clone(),
                    });
                    specs.extend(batch);
                }
                // Re-space arrivals exactly as the live admission path did.
                // A single-entry, count=1, non-manifest record is the plain
                // `SUBMIT` shape, which the daemon admits via `submit_burst`
                // (one submit-RPC of client-loop serialization between each
                // materialized job); everything else landed as one batched
                // arrival instant. Replaying a burst as a batch kept the ids
                // exact but collapsed the inter-RPC pacing, so post-recovery
                // age/fairshare state diverged from the pre-crash queue.
                let client_loop_burst = manifest.is_none()
                    && entries.len() == 1
                    && entries[0].entry.count == 1;
                let ids = if client_loop_burst {
                    sched.submit_burst(specs)
                } else {
                    sched.submit_batch(specs)
                };
                let got_first = ids.first().map(|j| j.0).unwrap_or(0);
                if ids.len() as u64 != *total_jobs || (!ids.is_empty() && got_first != *first_id)
                {
                    return Err(RecoveryError::Mismatch(format!(
                        "admit replay assigned ids {got_first}..+{} but the journal acked \
                         {first_id}..+{total_jobs}",
                        ids.len()
                    )));
                }
                if let Some(mid) = manifest {
                    registry.restore(*mid, spans);
                }
            }
            JournalRecord::Cancel { vtime, id } => {
                report.cancels_replayed += 1;
                if *vtime > sched.now() {
                    sched.run_until(*vtime);
                }
                // The cancel was acked pre-crash, so it normally lands; a
                // job that already ran to completion during replay is fine
                // (the cancel was a no-op race then, too).
                let _ = sched.cancel(JobId(*id));
            }
            // Segments lead with their checkpoint; the scan strips it, so
            // a checkpoint in the tail means a corrupted scan.
            JournalRecord::Checkpoint(_) => {
                return Err(RecoveryError::Mismatch(
                    "checkpoint record in the replay tail".into(),
                ));
            }
        }
    }

    report.recovered_vtime_secs = sched.now().as_secs_f64();
    report.next_id = sched.jobs_signature().1;
    report.manifests_restored = registry.len();
    Ok(RebuiltState {
        sched,
        registry,
        history: cp.history.clone(),
        report,
    })
}

/// Seed the fresh scheduler and registry from the checkpoint.
fn restore_checkpoint(
    sched: &mut Scheduler,
    registry: &mut ManifestRegistry,
    cp: &CheckpointState,
    report: &mut RecoveryReport,
) {
    sched.force_next_id(cp.next_id);
    registry.force_next_id(cp.next_manifest_id);
    for m in &cp.manifests {
        registry.restore(m.id, m.spans.clone());
    }
    report.jobs_restored = cp.jobs.len();
    report.history_restored = cp.history.len();
    for job in &cp.jobs {
        match job.state {
            JobState::Pending => report.restored_pending += 1,
            JobState::Running => report.restored_running += 1,
            JobState::Requeued => report.restored_requeued += 1,
            JobState::Suspended => report.restored_suspended += 1,
            // Terminal jobs are never checkpointed live (they retire into
            // history); tolerate them as plain restores if they appear.
            JobState::Completed | JobState::Cancelled => {}
        }
        sched.restore_job(
            JobId(job.id),
            job.spec.clone(),
            job.submit_time,
            job.requeue_count,
            &job.log,
            cp.vtime,
        );
    }
    // Arrivals are queued at cp.vtime; drain them so the recovered
    // scheduler's table is live before the tail replays.
    sched.run_until(cp.vtime);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::journal::{AdmitEntry, CheckpointJob};
    use crate::coordinator::manifest::ManifestEntry;
    use crate::job::{JobSpec, JobType, QosClass, UserId};
    use crate::sim::{SchedCosts, SimTime};

    fn sched_cfg() -> SchedulerConfig {
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
    }

    fn recovered(cp: CheckpointState, tail: Vec<JournalRecord>) -> RecoveredJournal {
        RecoveredJournal {
            checkpoint: cp,
            tail,
            torn_bytes: 0,
            segments_discarded: 0,
        }
    }

    #[test]
    fn genesis_plus_admit_tail_replays_to_the_acked_ids() {
        let entry = ManifestEntry::new(QosClass::Spot, JobType::TripleMode, 320, 9)
            .with_tag("replayed");
        let tail = vec![JournalRecord::Admit {
            vtime: SimTime::from_secs(5),
            first_id: 1,
            total_jobs: 1,
            manifest: Some(1),
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();
        assert_eq!(rb.report.admits_replayed, 1);
        assert_eq!(rb.report.jobs_restored, 0);
        assert!(rb.sched.now() >= SimTime::from_secs(5));
        let m = rb.registry.by_tag("replayed").expect("manifest restored");
        assert_eq!(m.spans[0].first, 1);
        assert_eq!(rb.sched.jobs().count(), 1);
    }

    #[test]
    fn admit_id_divergence_is_a_typed_mismatch() {
        // The journal claims first_id=42 but a fresh scheduler assigns 1.
        let entry = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9);
        let tail = vec![JournalRecord::Admit {
            vtime: SimTime::ZERO,
            first_id: 42,
            total_jobs: 1,
            manifest: None,
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        match rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        )) {
            Err(RecoveryError::Mismatch(msg)) => assert!(msg.contains("42"), "{msg}"),
            other => panic!("{:?}", other.map(|r| r.report)),
        }
    }

    #[test]
    fn checkpoint_jobs_restore_with_ids_states_and_log_facts() {
        let spec = JobSpec::spot(UserId(9), JobType::TripleMode, 320);
        let cp = CheckpointState {
            vtime: SimTime::from_secs(100),
            next_id: 8,
            next_manifest_id: 3,
            jobs: vec![CheckpointJob {
                id: 7,
                state: JobState::Running,
                submit_time: SimTime::from_secs(60),
                requeue_count: 2,
                spec,
                log: vec![(SimTime::from_secs(61), crate::sched::LogKind::Recognized)],
            }],
            history: Vec::new(),
            manifests: Vec::new(),
        };
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(cp, Vec::new())).unwrap();
        assert_eq!(rb.report.restored_running, 1);
        assert_eq!(rb.report.next_id, 8);
        let job = rb.sched.jobs().find(|j| j.id == JobId(7)).expect("restored");
        assert_eq!(job.submit_time, SimTime::from_secs(60));
        assert_eq!(job.requeue_count, 2);
        assert_eq!(
            rb.sched
                .log()
                .first(JobId(7), crate::sched::LogKind::Recognized),
            Some(SimTime::from_secs(61)),
            "pre-crash log facts survive"
        );
        // A post-recovery admission continues past the checkpointed id.
        let mut sched = rb.sched;
        let ids = sched.submit_batch(vec![JobSpec::spot(UserId(1), JobType::Array, 8)]);
        assert_eq!(ids[0], JobId(8), "next_id restored from checkpoint");
    }

    #[test]
    fn burst_replay_preserves_client_loop_arrival_pacing() {
        // Regression (durability follow-on): a plain `SUBMIT` of an
        // interactive individual spec expands into one job per task and is
        // admitted live via `submit_burst` — one submit RPC of client-loop
        // serialization between consecutive jobs. Replay used to land the
        // whole record as one batched instant: ids stayed exact but every
        // job's arrival (and so its age/fairshare state and queue order)
        // was wrong. Replay must reproduce the live spacing.
        let entry = ManifestEntry::new(QosClass::Normal, JobType::Individual, 4, 1)
            .with_run_secs(60.0);
        let vtime = SimTime::from_secs(5);

        // The live admission path, for the expected arrival schedule.
        let mut live = Scheduler::new(topology::tx2500(), sched_cfg());
        live.run_until(vtime);
        let live_ids = live.submit_burst(entry.materialize());
        assert_eq!(live_ids.len(), 4, "individual tasks=4 expands to 4 jobs");

        let tail = vec![JournalRecord::Admit {
            vtime,
            first_id: live_ids[0].0,
            total_jobs: 4,
            manifest: None,
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();

        let live_times: Vec<SimTime> = live_ids
            .iter()
            .map(|&id| live.job(id).expect("live job").submit_time)
            .collect();
        let replay_times: Vec<SimTime> = live_ids
            .iter()
            .map(|&id| rb.sched.job(id).expect("replayed job").submit_time)
            .collect();
        assert_eq!(
            live_times, replay_times,
            "replayed arrival pacing diverged from the live client-loop burst"
        );
        // The sentinel the old code failed: arrivals are *spaced*, not one
        // batched instant (queue order between bursts depends on this).
        assert!(
            replay_times.windows(2).all(|w| w[0] < w[1]),
            "burst arrivals collapsed to a batch: {replay_times:?}"
        );
    }

    #[test]
    fn batched_records_still_replay_as_one_arrival_instant() {
        // count>1 (batch SUBMIT) and manifest records keep the batched
        // replay: one RPC, one arrival instant — same as live admission.
        let entry = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_count(3);
        let tail = vec![JournalRecord::Admit {
            vtime: SimTime::ZERO,
            first_id: 1,
            total_jobs: 3,
            manifest: None,
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();
        let times: Vec<SimTime> = (1..=3)
            .map(|id| rb.sched.job(JobId(id)).expect("job").submit_time)
            .collect();
        assert!(
            times.windows(2).all(|w| w[0] == w[1]),
            "batched record must land at one instant: {times:?}"
        );
    }

    #[test]
    fn cancel_replay_lands_and_is_tolerant() {
        let entry = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9);
        let tail = vec![
            JournalRecord::Admit {
                vtime: SimTime::ZERO,
                first_id: 1,
                total_jobs: 1,
                manifest: None,
                entries: vec![AdmitEntry { index: 0, entry }],
            },
            JournalRecord::Cancel {
                vtime: SimTime::from_millis(1),
                id: 1,
            },
            // A second cancel of the same id was impossible to ack live,
            // but replay must not die on a no-op cancel.
            JournalRecord::Cancel {
                vtime: SimTime::from_millis(2),
                id: 1,
            },
        ];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();
        assert_eq!(rb.report.cancels_replayed, 2);
        let job = rb.sched.jobs().find(|j| j.id == JobId(1)).expect("job");
        assert_eq!(job.state, JobState::Cancelled);
    }

    #[test]
    fn report_display_mentions_the_key_counts() {
        let report = RecoveryReport {
            jobs_restored: 3,
            restored_running: 1,
            admits_replayed: 2,
            torn_bytes: 17,
            ..RecoveryReport::default()
        };
        let s = report.to_string();
        assert!(s.contains("jobs=3"), "{s}");
        assert!(s.contains("running=1"), "{s}");
        assert!(s.contains("admits=2"), "{s}");
        assert!(s.contains("torn_bytes=17"), "{s}");
    }
}
