//! Crash recovery: rebuild the scheduler, manifest registry, and history
//! table from a recovered journal (checkpoint + tail replay).
//!
//! The journal stores *inputs*, not scheduler state: an [`Admit`] record
//! carries the manifest entries and the id range the scheduler assigned,
//! and replay re-materializes and re-submits them in the original order at
//! the original virtual time. The scheduler's id assignment is
//! deterministic, so the replayed range must equal the journaled one — any
//! divergence is a [`RecoveryError::Mismatch`], never a silent re-numbering
//! (acked ids are a client-visible contract).
//!
//! Jobs that were Running/Suspended at the checkpoint are restored as
//! Pending and re-queued at the checkpoint's virtual time: the simulated
//! cluster's in-flight placements died with the process, exactly like
//! requeue-on-preemption, but their pre-crash event-log entries (and so
//! their first-recognized/dispatch facts) are preserved for `SJOB`/`WAIT`.
//!
//! [`Admit`]: JournalRecord::Admit

use super::daemon::ConfigError;
use super::journal::{CheckpointState, JournalError, JournalRecord, RecoveredJournal};
use super::manifest::{ManifestRegistry, ManifestSpan};
use super::snapshot::JobView;
use crate::cluster::{Cluster, PartitionId};
use crate::job::{JobId, JobState};
use crate::sched::{Scheduler, SchedulerConfig};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// Why recovery failed.
#[derive(Debug)]
pub enum RecoveryError {
    /// The journal itself could not be read (I/O or unrecoverable
    /// corruption).
    Journal(JournalError),
    /// Replay diverged from the journaled facts (e.g. the re-admitted id
    /// range differs from the acked one).
    Mismatch(String),
    /// The boot configuration does not match the on-disk journal (wrong
    /// shard layout, unreadable directory, …).
    Config(ConfigError),
}

impl fmt::Display for RecoveryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecoveryError::Journal(e) => write!(f, "journal: {e}"),
            RecoveryError::Mismatch(what) => write!(f, "replay mismatch: {what}"),
            RecoveryError::Config(e) => write!(f, "config: {e}"),
        }
    }
}

impl std::error::Error for RecoveryError {}

impl From<JournalError> for RecoveryError {
    fn from(e: JournalError) -> Self {
        RecoveryError::Journal(e)
    }
}

impl From<ConfigError> for RecoveryError {
    fn from(e: ConfigError) -> Self {
        RecoveryError::Config(e)
    }
}

/// What recovery did, typed — the daemon logs it and tests assert on it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryReport {
    /// Whole newer segments discarded (torn mid-checkpoint rotation).
    pub segments_discarded: usize,
    /// Torn-tail bytes truncated from the surviving segment.
    pub torn_bytes: u64,
    /// Tail records replayed after the checkpoint.
    pub records_replayed: usize,
    /// Of those, admissions.
    pub admits_replayed: usize,
    /// Of those, cancellations.
    pub cancels_replayed: usize,
    /// Live jobs restored from the checkpoint.
    pub jobs_restored: usize,
    /// Checkpoint jobs that were Pending at capture.
    pub restored_pending: usize,
    /// Checkpoint jobs that were Running at capture (re-queued).
    pub restored_running: usize,
    /// Checkpoint jobs that were Requeued at capture.
    pub restored_requeued: usize,
    /// Checkpoint jobs that were Suspended at capture (re-queued).
    pub restored_suspended: usize,
    /// Retired-history views restored.
    pub history_restored: usize,
    /// Manifests restored (checkpoint + tail).
    pub manifests_restored: usize,
    /// Virtual time after replay (seconds).
    pub recovered_vtime_secs: f64,
    /// The scheduler's next job id after replay (sharded: the global
    /// allocator watermark).
    pub next_id: u64,
    /// Sharded recovery only: cross-shard id-range leases dropped because
    /// a touched shard had neither the tail part nor a checkpoint past the
    /// lease — a torn, never-acked admission.
    pub leases_skipped_torn: usize,
}

impl fmt::Display for RecoveryReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "recovered vtime={:.3}s jobs={} (pending={} running={} requeued={} suspended={}) \
             history={} manifests={} replayed={} (admits={} cancels={}) torn_bytes={} \
             segments_discarded={} next_id={}",
            self.recovered_vtime_secs,
            self.jobs_restored,
            self.restored_pending,
            self.restored_running,
            self.restored_requeued,
            self.restored_suspended,
            self.history_restored,
            self.manifests_restored,
            self.records_replayed,
            self.admits_replayed,
            self.cancels_replayed,
            self.torn_bytes,
            self.segments_discarded,
            self.next_id,
        )?;
        if self.leases_skipped_torn > 0 {
            write!(f, " torn_leases={}", self.leases_skipped_torn)?;
        }
        Ok(())
    }
}

/// Everything [`rebuild`] hands back for the daemon to adopt.
pub struct RebuiltState {
    /// The replayed scheduler, advanced to the last journaled instant.
    pub sched: Scheduler,
    /// The manifest registry (checkpoint manifests + tail admissions).
    pub registry: ManifestRegistry,
    /// Retired-history views, original retirement order (the daemon
    /// re-inserts them through its capped table so pruning semantics
    /// match a never-crashed daemon).
    pub history: Vec<JobView>,
    /// The typed report.
    pub report: RecoveryReport,
}

/// Rebuild scheduler + registry + history from a recovered journal over a
/// fresh cluster. `cluster`/`sched_cfg` must match the crashed daemon's —
/// the journal records inputs, not topology.
pub fn rebuild(
    cluster: Cluster,
    sched_cfg: SchedulerConfig,
    recovered: &RecoveredJournal,
) -> Result<RebuiltState, RecoveryError> {
    let cp = &recovered.checkpoint;
    let mut report = RecoveryReport {
        segments_discarded: recovered.segments_discarded,
        torn_bytes: recovered.torn_bytes,
        records_replayed: recovered.tail.len(),
        ..RecoveryReport::default()
    };

    let mut sched = Scheduler::new(cluster, sched_cfg);
    let mut registry = ManifestRegistry::new();
    restore_checkpoint(&mut sched, &mut registry, cp, &mut report);

    for rec in &recovered.tail {
        match rec {
            JournalRecord::Admit {
                vtime,
                first_id,
                total_jobs,
                manifest,
                entries,
            } => {
                report.admits_replayed += 1;
                if *vtime > sched.now() {
                    sched.run_until(*vtime);
                }
                // Re-materialize in admission order; the scheduler's
                // deterministic id assignment reproduces the acked range.
                let mut specs = Vec::new();
                let mut spans: Vec<ManifestSpan> = Vec::with_capacity(entries.len());
                for ae in entries {
                    let batch = ae.entry.materialize();
                    spans.push(ManifestSpan {
                        index: ae.index,
                        first: first_id + specs.len() as u64,
                        count: batch.len() as u64,
                        tag: ae.entry.tag.clone(),
                    });
                    specs.extend(batch);
                }
                // Re-space arrivals exactly as the live admission path did.
                // A single-entry, count=1, non-manifest record is the plain
                // `SUBMIT` shape, which the daemon admits via `submit_burst`
                // (one submit-RPC of client-loop serialization between each
                // materialized job); everything else landed as one batched
                // arrival instant. Replaying a burst as a batch kept the ids
                // exact but collapsed the inter-RPC pacing, so post-recovery
                // age/fairshare state diverged from the pre-crash queue.
                let client_loop_burst = manifest.is_none()
                    && entries.len() == 1
                    && entries[0].entry.count == 1;
                let ids = if client_loop_burst {
                    sched.submit_burst(specs)
                } else {
                    sched.submit_batch(specs)
                };
                let got_first = ids.first().map(|j| j.0).unwrap_or(0);
                if ids.len() as u64 != *total_jobs || (!ids.is_empty() && got_first != *first_id)
                {
                    return Err(RecoveryError::Mismatch(format!(
                        "admit replay assigned ids {got_first}..+{} but the journal acked \
                         {first_id}..+{total_jobs}",
                        ids.len()
                    )));
                }
                if let Some(mid) = manifest {
                    registry.restore(*mid, spans);
                }
            }
            JournalRecord::Cancel { vtime, id } => {
                report.cancels_replayed += 1;
                if *vtime > sched.now() {
                    sched.run_until(*vtime);
                }
                // The cancel was acked pre-crash, so it normally lands; a
                // job that already ran to completion during replay is fine
                // (the cancel was a no-op race then, too).
                let _ = sched.cancel(JobId(*id));
            }
            // Segments lead with their checkpoint; the scan strips it, so
            // a checkpoint in the tail means a corrupted scan.
            JournalRecord::Checkpoint(_) => {
                return Err(RecoveryError::Mismatch(
                    "checkpoint record in the replay tail".into(),
                ));
            }
            // Tag-4 parts only ever land in per-shard journals.
            JournalRecord::ShardAdmit { lease, .. } => {
                return Err(RecoveryError::Mismatch(format!(
                    "sharded admit part (lease {lease}) in a single-shard journal"
                )));
            }
        }
    }

    report.recovered_vtime_secs = sched.now().as_secs_f64();
    report.next_id = sched.jobs_signature().1;
    report.manifests_restored = registry.len();
    Ok(RebuiltState {
        sched,
        registry,
        history: cp.history.clone(),
        report,
    })
}

/// Everything [`rebuild_sharded`] hands back.
pub struct RebuiltShardedState {
    /// One replayed scheduler per shard-plan slice, same order.
    pub scheds: Vec<Scheduler>,
    /// The merged manifest registry (newest checkpoint + tail leases).
    pub registry: ManifestRegistry,
    /// Merged retired-history views.
    pub history: Vec<JobView>,
    /// The recovered global id-allocator watermark (the next id the
    /// allocator must hand out).
    pub next_id: u64,
    /// Per-shard applied-lease watermark: `max(checkpoint.applied_lease,
    /// highest lease replayed from that shard's tail)`. Torn leases are
    /// excluded — counting a dropped lease as applied would falsely mark
    /// it checkpoint-absorbed on the *next* recovery. The daemon seeds
    /// each journal slot's counter from this, so fresh checkpoints carry
    /// a truthful watermark.
    pub applied_leases: Vec<u64>,
    /// The typed report (aggregated across shards).
    pub report: RecoveryReport,
}

/// Rebuild a sharded daemon from every shard's recovered journal plus the
/// allocator-log id watermark. `plan` must be the writer's
/// [`super::shards::shard_plan`] — the slices are what make per-shard id
/// replay deterministic. `recovered[i]` is shard `i`'s journal.
///
/// Cross-shard admissions replay under the **lease completeness rule**: a
/// lease is replayed iff every shard in its touched set either has its
/// part in the tail or checkpointed past the lease (`applied_lease`).
/// Anything else was torn mid-admission — the client was never acked (the
/// ack waits for every append) — and every surviving part is dropped, so
/// cross-shard manifests stay atomic: fully admitted or fully absent.
pub fn rebuild_sharded(
    plan: &[(PartitionId, &'static str, Cluster)],
    sched_cfg: SchedulerConfig,
    recovered: &[RecoveredJournal],
    alloc_watermark_id: u64,
) -> Result<RebuiltShardedState, RecoveryError> {
    if plan.len() != recovered.len() {
        return Err(RecoveryError::Mismatch(format!(
            "shard plan has {} slices but {} shard journals were recovered",
            plan.len(),
            recovered.len()
        )));
    }
    let nshards = plan.len();
    let mut report = RecoveryReport {
        segments_discarded: recovered.iter().map(|r| r.segments_discarded).sum(),
        torn_bytes: recovered.iter().map(|r| r.torn_bytes).sum(),
        records_replayed: recovered.iter().map(|r| r.tail.len()).sum(),
        ..RecoveryReport::default()
    };

    // Pass 1: index every lease's surviving parts and declared shard set.
    let mut lease_present: BTreeMap<u64, BTreeSet<u32>> = BTreeMap::new();
    let mut lease_declared: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    for (idx, rec) in recovered.iter().enumerate() {
        for r in &rec.tail {
            if let JournalRecord::ShardAdmit { lease, shards, .. } = r {
                for &s in shards {
                    if s as usize >= nshards {
                        return Err(RecoveryError::Mismatch(format!(
                            "lease {lease} touches shard {s} but the plan has {nshards} shards"
                        )));
                    }
                }
                if let Some(prev) = lease_declared.get(lease) {
                    if prev != shards {
                        return Err(RecoveryError::Mismatch(format!(
                            "lease {lease} parts disagree on the touched shard set"
                        )));
                    }
                } else {
                    lease_declared.insert(*lease, shards.clone());
                }
                lease_present.entry(*lease).or_default().insert(idx as u32);
            }
        }
    }
    let complete = |lease: u64| -> bool {
        let declared = &lease_declared[&lease];
        let present = &lease_present[&lease];
        declared.iter().all(|&s| {
            present.contains(&s) || recovered[s as usize].checkpoint.applied_lease >= lease
        })
    };
    let torn: BTreeSet<u64> = lease_declared
        .keys()
        .filter(|&&l| !complete(l))
        .copied()
        .collect();
    report.leases_skipped_torn = torn.len();

    // Pass 2: registry + history from the checkpoint with the newest
    // captured registry (highest global_seq — captures are sequenced under
    // the registry lock, and the registry only grows), then fill ids the
    // older checkpoints saw that it did not (only possible across the
    // checkpoints' capture skew; `restore_if_absent` keeps the newest
    // authoritative).
    let newest = (0..nshards)
        .max_by_key(|&i| recovered[i].checkpoint.global_seq)
        .unwrap_or(0);
    let mut registry = ManifestRegistry::new();
    let mut history: Vec<JobView> = Vec::new();
    {
        let cp = &recovered[newest].checkpoint;
        registry.force_next_id(cp.next_manifest_id);
        for m in &cp.manifests {
            registry.restore(m.id, m.spans.clone());
        }
        history.extend(cp.history.iter().cloned());
    }
    for (i, rec) in recovered.iter().enumerate() {
        if i == newest {
            continue;
        }
        let cp = &rec.checkpoint;
        registry.force_next_id(cp.next_manifest_id);
        for m in &cp.manifests {
            registry.restore_if_absent(m.id, m.spans.clone());
        }
        let seen: BTreeSet<u64> = history.iter().map(|v| v.id).collect();
        history.extend(cp.history.iter().filter(|v| !seen.contains(&v.id)).cloned());
    }
    report.history_restored = history.len();

    // Pass 3: seed each shard's scheduler from its own checkpoint, then
    // replay its tail, skipping parts of torn leases. Cross-shard manifest
    // spans are accumulated from every replayed part and registered after
    // the per-shard replays (a checkpoint that absorbed the lease already
    // carries the manifest; `restore_if_absent` keeps it authoritative).
    let mut scheds = Vec::with_capacity(nshards);
    let mut applied_leases = Vec::with_capacity(nshards);
    let mut tail_manifests: BTreeMap<u64, Vec<ManifestSpan>> = BTreeMap::new();
    let mut max_run_end = 0u64;
    for (idx, ((_, _, slice), rec)) in plan.iter().zip(recovered).enumerate() {
        let mut sched = Scheduler::new(slice.clone(), sched_cfg.clone());
        let mut applied = rec.checkpoint.applied_lease;
        restore_checkpoint_jobs(&mut sched, &rec.checkpoint, &mut report);
        for r in &rec.tail {
            match r {
                JournalRecord::ShardAdmit {
                    vtime,
                    lease,
                    manifest,
                    runs,
                    ..
                } => {
                    if torn.contains(lease) {
                        continue;
                    }
                    applied = applied.max(*lease);
                    report.admits_replayed += 1;
                    if *vtime > sched.now() {
                        sched.run_until(*vtime);
                    }
                    // The plain-`SUBMIT` shape replays as a client-loop
                    // burst, same as the single-shard path.
                    let client_loop_burst = manifest.is_none()
                        && runs.len() == 1
                        && runs[0].entries.len() == 1
                        && runs[0].entries[0].entry.count == 1;
                    for run in runs {
                        sched.force_next_id(run.first_id);
                        let mut specs = Vec::new();
                        let mut spans: Vec<ManifestSpan> = Vec::with_capacity(run.entries.len());
                        for ae in &run.entries {
                            let batch = ae.entry.materialize();
                            spans.push(ManifestSpan {
                                index: ae.index,
                                first: run.first_id + specs.len() as u64,
                                count: batch.len() as u64,
                                tag: ae.entry.tag.clone(),
                            });
                            specs.extend(batch);
                        }
                        let total = specs.len() as u64;
                        let ids = if client_loop_burst {
                            sched.submit_burst(specs)
                        } else {
                            sched.submit_batch(specs)
                        };
                        let got_first = ids.first().map(|j| j.0).unwrap_or(0);
                        if ids.len() as u64 != total
                            || (!ids.is_empty() && got_first != run.first_id)
                        {
                            return Err(RecoveryError::Mismatch(format!(
                                "shard {idx} replay of lease {lease} assigned ids \
                                 {got_first}..+{} but the journal acked {}..+{total}",
                                ids.len(),
                                run.first_id
                            )));
                        }
                        max_run_end = max_run_end.max(run.first_id + total);
                        if let Some(mid) = manifest {
                            tail_manifests.entry(*mid).or_default().extend(spans);
                        }
                    }
                }
                JournalRecord::Cancel { vtime, id } => {
                    report.cancels_replayed += 1;
                    if *vtime > sched.now() {
                        sched.run_until(*vtime);
                    }
                    let _ = sched.cancel(JobId(*id));
                }
                JournalRecord::Checkpoint(_) => {
                    return Err(RecoveryError::Mismatch(format!(
                        "checkpoint record in shard {idx}'s replay tail"
                    )));
                }
                // Tag-1 records never land in a sharded journal.
                JournalRecord::Admit { first_id, .. } => {
                    return Err(RecoveryError::Mismatch(format!(
                        "single-shard admit (first_id {first_id}) in shard {idx}'s journal"
                    )));
                }
            }
        }
        scheds.push(sched);
        applied_leases.push(applied);
    }
    for (mid, mut spans) in tail_manifests {
        spans.sort_by_key(|s| s.index);
        registry.restore_if_absent(mid, spans);
    }

    let cp_next_id = recovered.iter().map(|r| r.checkpoint.next_id).max().unwrap_or(1);
    let next_id = alloc_watermark_id.max(cp_next_id).max(max_run_end).max(1);
    report.next_id = next_id;
    report.recovered_vtime_secs = scheds
        .iter()
        .map(|s| s.now().as_secs_f64())
        .fold(0.0, f64::max);
    report.manifests_restored = registry.len();
    Ok(RebuiltShardedState {
        scheds,
        registry,
        history,
        next_id,
        applied_leases,
        report,
    })
}

/// Seed the fresh scheduler and registry from the checkpoint.
fn restore_checkpoint(
    sched: &mut Scheduler,
    registry: &mut ManifestRegistry,
    cp: &CheckpointState,
    report: &mut RecoveryReport,
) {
    registry.force_next_id(cp.next_manifest_id);
    for m in &cp.manifests {
        registry.restore(m.id, m.spans.clone());
    }
    report.history_restored = cp.history.len();
    restore_checkpoint_jobs(sched, cp, report);
}

/// The job half of a checkpoint restore (sharded recovery seeds each
/// shard's scheduler from its own checkpoint but merges registry/history
/// separately).
fn restore_checkpoint_jobs(sched: &mut Scheduler, cp: &CheckpointState, report: &mut RecoveryReport) {
    sched.force_next_id(cp.next_id);
    report.jobs_restored += cp.jobs.len();
    for job in &cp.jobs {
        match job.state {
            JobState::Pending => report.restored_pending += 1,
            JobState::Running => report.restored_running += 1,
            JobState::Requeued => report.restored_requeued += 1,
            JobState::Suspended => report.restored_suspended += 1,
            // Terminal jobs are never checkpointed live (they retire into
            // history); tolerate them as plain restores if they appear.
            JobState::Completed | JobState::Cancelled => {}
        }
        sched.restore_job(
            JobId(job.id),
            job.spec.clone(),
            job.submit_time,
            job.requeue_count,
            &job.log,
            cp.vtime,
        );
    }
    // Arrivals are queued at cp.vtime; drain them so the recovered
    // scheduler's table is live before the tail replays.
    sched.run_until(cp.vtime);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::journal::{AdmitEntry, CheckpointJob};
    use crate::coordinator::manifest::ManifestEntry;
    use crate::job::{JobSpec, JobType, QosClass, UserId};
    use crate::sim::{SchedCosts, SimTime};

    fn sched_cfg() -> SchedulerConfig {
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
    }

    fn recovered(cp: CheckpointState, tail: Vec<JournalRecord>) -> RecoveredJournal {
        RecoveredJournal {
            checkpoint: cp,
            tail,
            torn_bytes: 0,
            segments_discarded: 0,
        }
    }

    #[test]
    fn genesis_plus_admit_tail_replays_to_the_acked_ids() {
        let entry = ManifestEntry::new(QosClass::Spot, JobType::TripleMode, 320, 9)
            .with_tag("replayed");
        let tail = vec![JournalRecord::Admit {
            vtime: SimTime::from_secs(5),
            first_id: 1,
            total_jobs: 1,
            manifest: Some(1),
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();
        assert_eq!(rb.report.admits_replayed, 1);
        assert_eq!(rb.report.jobs_restored, 0);
        assert!(rb.sched.now() >= SimTime::from_secs(5));
        let m = rb.registry.by_tag("replayed").expect("manifest restored");
        assert_eq!(m.spans[0].first, 1);
        assert_eq!(rb.sched.jobs().count(), 1);
    }

    #[test]
    fn admit_id_divergence_is_a_typed_mismatch() {
        // The journal claims first_id=42 but a fresh scheduler assigns 1.
        let entry = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9);
        let tail = vec![JournalRecord::Admit {
            vtime: SimTime::ZERO,
            first_id: 42,
            total_jobs: 1,
            manifest: None,
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        match rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        )) {
            Err(RecoveryError::Mismatch(msg)) => assert!(msg.contains("42"), "{msg}"),
            other => panic!("{:?}", other.map(|r| r.report)),
        }
    }

    #[test]
    fn checkpoint_jobs_restore_with_ids_states_and_log_facts() {
        let spec = JobSpec::spot(UserId(9), JobType::TripleMode, 320);
        let cp = CheckpointState {
            vtime: SimTime::from_secs(100),
            next_id: 8,
            next_manifest_id: 3,
            jobs: vec![CheckpointJob {
                id: 7,
                state: JobState::Running,
                submit_time: SimTime::from_secs(60),
                requeue_count: 2,
                spec,
                log: vec![(SimTime::from_secs(61), crate::sched::LogKind::Recognized)],
            }],
            history: Vec::new(),
            manifests: Vec::new(),
            global_seq: 0,
            applied_lease: 0,
        };
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(cp, Vec::new())).unwrap();
        assert_eq!(rb.report.restored_running, 1);
        assert_eq!(rb.report.next_id, 8);
        let job = rb.sched.jobs().find(|j| j.id == JobId(7)).expect("restored");
        assert_eq!(job.submit_time, SimTime::from_secs(60));
        assert_eq!(job.requeue_count, 2);
        assert_eq!(
            rb.sched
                .log()
                .first(JobId(7), crate::sched::LogKind::Recognized),
            Some(SimTime::from_secs(61)),
            "pre-crash log facts survive"
        );
        // A post-recovery admission continues past the checkpointed id.
        let mut sched = rb.sched;
        let ids = sched.submit_batch(vec![JobSpec::spot(UserId(1), JobType::Array, 8)]);
        assert_eq!(ids[0], JobId(8), "next_id restored from checkpoint");
    }

    #[test]
    fn burst_replay_preserves_client_loop_arrival_pacing() {
        // Regression (durability follow-on): a plain `SUBMIT` of an
        // interactive individual spec expands into one job per task and is
        // admitted live via `submit_burst` — one submit RPC of client-loop
        // serialization between consecutive jobs. Replay used to land the
        // whole record as one batched instant: ids stayed exact but every
        // job's arrival (and so its age/fairshare state and queue order)
        // was wrong. Replay must reproduce the live spacing.
        let entry = ManifestEntry::new(QosClass::Normal, JobType::Individual, 4, 1)
            .with_run_secs(60.0);
        let vtime = SimTime::from_secs(5);

        // The live admission path, for the expected arrival schedule.
        let mut live = Scheduler::new(topology::tx2500(), sched_cfg());
        live.run_until(vtime);
        let live_ids = live.submit_burst(entry.materialize());
        assert_eq!(live_ids.len(), 4, "individual tasks=4 expands to 4 jobs");

        let tail = vec![JournalRecord::Admit {
            vtime,
            first_id: live_ids[0].0,
            total_jobs: 4,
            manifest: None,
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();

        let live_times: Vec<SimTime> = live_ids
            .iter()
            .map(|&id| live.job(id).expect("live job").submit_time)
            .collect();
        let replay_times: Vec<SimTime> = live_ids
            .iter()
            .map(|&id| rb.sched.job(id).expect("replayed job").submit_time)
            .collect();
        assert_eq!(
            live_times, replay_times,
            "replayed arrival pacing diverged from the live client-loop burst"
        );
        // The sentinel the old code failed: arrivals are *spaced*, not one
        // batched instant (queue order between bursts depends on this).
        assert!(
            replay_times.windows(2).all(|w| w[0] < w[1]),
            "burst arrivals collapsed to a batch: {replay_times:?}"
        );
    }

    #[test]
    fn batched_records_still_replay_as_one_arrival_instant() {
        // count>1 (batch SUBMIT) and manifest records keep the batched
        // replay: one RPC, one arrival instant — same as live admission.
        let entry = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_count(3);
        let tail = vec![JournalRecord::Admit {
            vtime: SimTime::ZERO,
            first_id: 1,
            total_jobs: 3,
            manifest: None,
            entries: vec![AdmitEntry { index: 0, entry }],
        }];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();
        let times: Vec<SimTime> = (1..=3)
            .map(|id| rb.sched.job(JobId(id)).expect("job").submit_time)
            .collect();
        assert!(
            times.windows(2).all(|w| w[0] == w[1]),
            "batched record must land at one instant: {times:?}"
        );
    }

    #[test]
    fn cancel_replay_lands_and_is_tolerant() {
        let entry = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9);
        let tail = vec![
            JournalRecord::Admit {
                vtime: SimTime::ZERO,
                first_id: 1,
                total_jobs: 1,
                manifest: None,
                entries: vec![AdmitEntry { index: 0, entry }],
            },
            JournalRecord::Cancel {
                vtime: SimTime::from_millis(1),
                id: 1,
            },
            // A second cancel of the same id was impossible to ack live,
            // but replay must not die on a no-op cancel.
            JournalRecord::Cancel {
                vtime: SimTime::from_millis(2),
                id: 1,
            },
        ];
        let rb = rebuild(topology::tx2500(), sched_cfg(), &recovered(
            CheckpointState::genesis(),
            tail,
        ))
        .unwrap();
        assert_eq!(rb.report.cancels_replayed, 2);
        let job = rb.sched.jobs().find(|j| j.id == JobId(1)).expect("job");
        assert_eq!(job.state, JobState::Cancelled);
    }

    #[test]
    fn report_display_mentions_the_key_counts() {
        let report = RecoveryReport {
            jobs_restored: 3,
            restored_running: 1,
            admits_replayed: 2,
            torn_bytes: 17,
            leases_skipped_torn: 1,
            ..RecoveryReport::default()
        };
        let s = report.to_string();
        assert!(s.contains("jobs=3"), "{s}");
        assert!(s.contains("running=1"), "{s}");
        assert!(s.contains("admits=2"), "{s}");
        assert!(s.contains("torn_bytes=17"), "{s}");
        assert!(s.contains("torn_leases=1"), "{s}");
    }

    // ----------------------------------------------------------- sharded

    use crate::coordinator::journal::AdmitRun;
    use crate::coordinator::manifest::RegisteredManifest;
    use crate::coordinator::shards::shard_plan;

    fn dual_plan() -> Vec<(PartitionId, &'static str, Cluster)> {
        shard_plan(&topology::tx2500(), &sched_cfg(), 2)
    }

    /// One cross-shard lease: 2 interactive jobs on shard 0, 1 spot job on
    /// shard 1, manifest id 1.
    fn lease_parts() -> (JournalRecord, JournalRecord) {
        let e0 = ManifestEntry::new(QosClass::Normal, JobType::Array, 8, 1)
            .with_count(2)
            .with_tag("xshard");
        let e1 = ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_tag("xshard");
        let header = |runs| JournalRecord::ShardAdmit {
            vtime: SimTime::from_secs(1),
            lease: 1,
            lease_first: 1,
            lease_total: 3,
            shards: vec![0, 1],
            manifest: Some(1),
            runs,
        };
        (
            header(vec![AdmitRun {
                first_id: 1,
                entries: vec![AdmitEntry { index: 0, entry: e0 }],
            }]),
            header(vec![AdmitRun {
                first_id: 3,
                entries: vec![AdmitEntry { index: 1, entry: e1 }],
            }]),
        )
    }

    #[test]
    fn sharded_complete_lease_replays_across_shards() {
        let (part0, part1) = lease_parts();
        let rec = vec![
            recovered(CheckpointState::genesis(), vec![part0]),
            recovered(CheckpointState::genesis(), vec![part1]),
        ];
        let rb = rebuild_sharded(&dual_plan(), sched_cfg(), &rec, 4).unwrap();
        assert_eq!(rb.report.admits_replayed, 2, "both parts replay");
        assert_eq!(rb.report.leases_skipped_torn, 0);
        let mut ids0: Vec<u64> = rb.scheds[0].jobs().map(|j| j.id.0).collect();
        let mut ids1: Vec<u64> = rb.scheds[1].jobs().map(|j| j.id.0).collect();
        ids0.sort_unstable();
        ids1.sort_unstable();
        assert_eq!(ids0, vec![1, 2], "shard 0 reproduces its acked ids");
        assert_eq!(ids1, vec![3], "shard 1 reproduces its acked id");
        assert_eq!(rb.next_id, 4, "allocator resumes past the lease");
        let m = rb.registry.get(1).expect("cross-shard manifest restored");
        assert_eq!(m.spans.len(), 2, "spans from both shards' parts");
        assert_eq!((m.spans[0].index, m.spans[0].first, m.spans[0].count), (0, 1, 2));
        assert_eq!((m.spans[1].index, m.spans[1].first, m.spans[1].count), (1, 3, 1));
        assert!(rb.registry.by_tag("xshard").is_some());
    }

    #[test]
    fn torn_lease_drops_every_part() {
        // Shard 0's part survived; shard 1 crashed before its append and
        // never checkpointed past the lease. The admission was never acked
        // (the ack waits for every shard's append), so recovery must drop
        // shard 0's part too — cross-shard manifests are atomic.
        let (part0, _) = lease_parts();
        let rec = vec![
            recovered(CheckpointState::genesis(), vec![part0]),
            recovered(CheckpointState::genesis(), Vec::new()),
        ];
        let rb = rebuild_sharded(&dual_plan(), sched_cfg(), &rec, 4).unwrap();
        assert_eq!(rb.report.leases_skipped_torn, 1);
        assert_eq!(rb.report.admits_replayed, 0);
        assert_eq!(rb.scheds[0].jobs().count(), 0, "dropped whole");
        assert_eq!(rb.scheds[1].jobs().count(), 0);
        assert!(rb.registry.get(1).is_none(), "no partial manifest");
        assert_eq!(rb.next_id, 4, "the leased ids stay burned (watermark)");
    }

    #[test]
    fn checkpoint_absorbed_part_completes_the_lease() {
        // Shard 1 checkpointed *after* applying its part (applied_lease =
        // 1) and the rotation truncated the part from its tail; shard 0
        // still has its part in the tail. The lease is complete: shard 0
        // replays, shard 1 restores from its checkpoint.
        let (part0, _) = lease_parts();
        let spot_cp = CheckpointState {
            vtime: SimTime::from_secs(2),
            next_id: 4,
            next_manifest_id: 2,
            jobs: vec![CheckpointJob {
                id: 3,
                state: JobState::Pending,
                submit_time: SimTime::from_secs(1),
                requeue_count: 0,
                spec: JobSpec::spot(UserId(9), JobType::Array, 8),
                log: Vec::new(),
            }],
            history: Vec::new(),
            manifests: vec![RegisteredManifest {
                id: 1,
                spans: vec![
                    ManifestSpan {
                        index: 0,
                        first: 1,
                        count: 2,
                        tag: Some(std::sync::Arc::from("xshard")),
                    },
                    ManifestSpan {
                        index: 1,
                        first: 3,
                        count: 1,
                        tag: Some(std::sync::Arc::from("xshard")),
                    },
                ],
                tag: Some(std::sync::Arc::from("xshard")),
            }],
            global_seq: 5,
            applied_lease: 1,
        };
        let rec = vec![
            recovered(CheckpointState::genesis(), vec![part0]),
            recovered(spot_cp, Vec::new()),
        ];
        let rb = rebuild_sharded(&dual_plan(), sched_cfg(), &rec, 4).unwrap();
        assert_eq!(rb.report.leases_skipped_torn, 0, "checkpoint absorbs the part");
        assert_eq!(rb.report.admits_replayed, 1, "only shard 0 replays from tail");
        let mut ids0: Vec<u64> = rb.scheds[0].jobs().map(|j| j.id.0).collect();
        let ids1: Vec<u64> = rb.scheds[1].jobs().map(|j| j.id.0).collect();
        ids0.sort_unstable();
        assert_eq!(ids0, vec![1, 2]);
        assert_eq!(ids1, vec![3], "restored from the checkpoint, not the tail");
        let m = rb.registry.get(1).expect("manifest from the newest checkpoint");
        assert_eq!(m.spans.len(), 2, "checkpoint registry is authoritative");
        assert_eq!(rb.next_id, 4);
    }

    #[test]
    fn single_shard_record_in_sharded_journal_is_mismatch() {
        let entry = ManifestEntry::new(QosClass::Normal, JobType::Array, 8, 1);
        let rec = vec![
            recovered(
                CheckpointState::genesis(),
                vec![JournalRecord::Admit {
                    vtime: SimTime::ZERO,
                    first_id: 1,
                    total_jobs: 1,
                    manifest: None,
                    entries: vec![AdmitEntry { index: 0, entry }],
                }],
            ),
            recovered(CheckpointState::genesis(), Vec::new()),
        ];
        match rebuild_sharded(&dual_plan(), sched_cfg(), &rec, 1) {
            Err(RecoveryError::Mismatch(msg)) => {
                assert!(msg.contains("single-shard admit"), "{msg}")
            }
            other => panic!("{:?}", other.map(|r| r.report)),
        }
    }
}
