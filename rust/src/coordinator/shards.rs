//! Partition-sharded scheduler: the daemon's back-end shard layer.
//!
//! The unsharded coordinator funnels every mutation through one scheduler
//! mutex — the collapse mode the node-based-scheduling literature observes
//! at high volumes of short jobs. This module splits the scheduler along
//! the cluster's existing partition model: with `shard_count > 1`, each
//! partition gets its **own** [`Scheduler`] (own mutex, own priority
//! buckets, own EASY shadow, own snapshot delta) over a disjoint slice of
//! the node pool, so submissions to disjoint partitions never contend.
//!
//! Cross-shard concerns go through an **epoch/sequence protocol on the
//! publish path** rather than a cross-shard lock:
//!
//! * **Global job ids.** Ids come from one global atomic allocator
//!   ([`SchedShards::allocate_ids`], called under the target shard's
//!   mutex); each shard's internal counter is fast-forwarded with
//!   [`Scheduler::force_next_id`] before the submit, so ids stay globally
//!   unique and a single RPC's ids stay contiguous — even when the RPC
//!   spans shards (cross-partition `MSUBMIT` locks every touched shard in
//!   ascending index order, then allocates one contiguous range).
//! * **One coherent snapshot.** Every shard keeps a per-shard
//!   [`SchedSnapshot`] slot, captured under its own mutex with the usual
//!   delta sharing. A publish takes the next **epoch** from a global
//!   sequence and k-way-merges the slots into one id-sorted global
//!   snapshot ([`SchedSnapshot::merged`]); the daemon swaps it in only if
//!   the epoch is newer than the published one, so concurrent per-shard
//!   publishes can race and readers still observe a monotone, internally
//!   consistent view. Readers (`SQUEUE`/`SJOB`/`STATS`/`UTIL`/`WAIT`)
//!   never learn that shards exist.
//! * **Fairshare / preemption.** Each shard enforces fairshare and
//!   preemption over its own partition and node slice; the merged
//!   snapshot aggregates the counters. With the paper's dual layout the
//!   spot partition owns its slice outright, so cross-pool preemption
//!   does not arise in sharded mode — the trade the ROADMAP's sharding
//!   direction calls out, and why `shard_count = 1` (exactly the
//!   unsharded daemon, byte-for-byte) remains the default.
//!
//! Durability composes with sharding (PR 8): each shard owns a journal
//! under `shard-<i>/` and the global id allocator persists id-range
//! leases in an allocator log, so recovery can rebuild the same shard
//! layout ([`shard_plan`] is deterministic in `(cluster, cfg, count)`),
//! replay every shard journal, and re-seat the global allocator at the
//! lease watermark ([`SchedShards::sharded_from`]).

use super::snapshot::SchedSnapshot;
use crate::cluster::{Cluster, PartitionId, PartitionLayout};
use crate::job::QosClass;
use crate::metrics::LogHistogram;
use crate::sched::{Scheduler, SchedulerConfig};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};

/// One scheduler shard: a full [`Scheduler`] over a slice of the cluster,
/// plus its published per-shard snapshot slot and lock metrics.
struct ShardSlot {
    /// Partition this shard owns (shard 0 owns partition 0, …). In
    /// single-shard mode the one slot owns every partition and this is
    /// partition 0.
    partition: PartitionId,
    /// Partition name (`interactive`, `spot`, `shared`) for STATS/UTIL.
    label: &'static str,
    sched: Mutex<Scheduler>,
    /// Latest snapshot captured under this shard's mutex (delta-shared
    /// with its predecessor). The merge path reads these slots.
    snapshot: RwLock<Arc<SchedSnapshot>>,
    /// Mutex acquisitions on this shard.
    locks: AtomicU64,
    /// Hold-time histogram for this shard's mutex (ns).
    lock_hold: Mutex<LogHistogram>,
}

/// A point-in-time stat row for one scheduler shard (feeds the `STATS` v2
/// `shard kind=sched` records and the shard bench).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedShardStat {
    /// Shard index.
    pub index: usize,
    /// Partition name this shard owns.
    pub label: String,
    /// Mutex acquisitions so far.
    pub locks: u64,
    /// p99 mutex hold (ns).
    pub lock_hold_p99_ns: u64,
    /// Max mutex hold (ns).
    pub lock_hold_max_ns: u64,
    /// Pending jobs in this shard's latest snapshot (queue depth).
    pub pending: usize,
    /// Running jobs in this shard's latest snapshot.
    pub running: usize,
    /// Dispatches this shard performed.
    pub dispatches: u64,
}

/// The deterministic shard layout for `(cluster, cfg, count)`: which
/// partition each shard owns and the node slice it gets. One entry (the
/// whole cluster) when sharding degenerates — single partition,
/// `count <= 1`, or fewer nodes than shards. Both [`SchedShards::sharded`]
/// and crash recovery build from this, so a recovered daemon reproduces
/// the writer's slices exactly (the id-determinism contract per shard).
pub fn shard_plan(
    cluster: &Cluster,
    cfg: &SchedulerConfig,
    count: usize,
) -> Vec<(PartitionId, &'static str, Cluster)> {
    let partitions = cfg.layout.partitions();
    let want = count.min(partitions.len());
    let nodes = cluster.node_count();
    if want <= 1 || (nodes as usize) < want {
        return vec![(PartitionId(0), partitions[0].name, cluster.clone())];
    }
    let cores = cluster.cores_per_node();
    let base = nodes / want as u32;
    let rem = (nodes % want as u32) as usize;
    partitions
        .into_iter()
        .take(want)
        .enumerate()
        .map(|(i, p)| {
            let n = base + u32::from(i < rem);
            (p.id, p.name, Cluster::homogeneous(n, cores))
        })
        .collect()
}

/// The shard set. `shard_count = 1` is the unsharded daemon: one scheduler
/// over the whole cluster, ids allocated by the scheduler itself, and the
/// daemon publishes the shard-0 snapshot directly (no merge, no epoch).
pub struct SchedShards {
    shards: Vec<ShardSlot>,
    /// Global id allocator (sharded mode): the next job id to hand out.
    /// Matches the scheduler's own initial counter (ids start at 1).
    next_id: AtomicU64,
    /// Global publish sequence (sharded mode): each merged snapshot gets
    /// the next epoch, and the daemon only swaps forward.
    epoch: AtomicU64,
    layout: PartitionLayout,
}

impl SchedShards {
    /// One shard over the whole cluster — exactly the unsharded daemon.
    pub fn single(cluster: Cluster, cfg: SchedulerConfig) -> Self {
        let layout = cfg.layout;
        let label = layout.partitions()[0].name;
        let sched = Scheduler::new(cluster, cfg);
        Self::from_scheds(vec![(PartitionId(0), label, sched)], layout)
    }

    /// Wrap an already-built scheduler (crash recovery rebuilds one
    /// scheduler and hands it over; recovery is single-shard by contract).
    pub fn single_from(sched: Scheduler) -> Self {
        let layout = sched.config().layout;
        let label = layout.partitions()[0].name;
        Self::from_scheds(vec![(PartitionId(0), label, sched)], layout)
    }

    /// One shard per partition, each over a proportional slice of the node
    /// pool. Falls back to [`SchedShards::single`] when the layout has one
    /// partition, when `count <= 1`, or when the cluster is too small to
    /// give every shard at least one node. `count` beyond the partition
    /// count is clamped — the partition model is the sharding model.
    pub fn sharded(cluster: Cluster, cfg: SchedulerConfig, count: usize) -> Self {
        let plan = shard_plan(&cluster, &cfg, count);
        if plan.len() <= 1 {
            return Self::single(cluster, cfg);
        }
        let layout = cfg.layout;
        let scheds = plan
            .into_iter()
            .map(|(id, name, slice)| (id, name, Scheduler::new(slice, cfg.clone())))
            .collect();
        Self::from_scheds(scheds, layout)
    }

    /// Rebuild a sharded set from recovery: pre-replayed schedulers (one
    /// per [`shard_plan`] slice, same order) plus the recovered global id
    /// watermark. The caller guarantees `scheds` matches the plan the
    /// writer ran with — [`shard_plan`] is deterministic in
    /// `(cluster, cfg, count)`, which is how the guarantee is met.
    pub fn sharded_from(
        scheds: Vec<(PartitionId, &'static str, Scheduler)>,
        layout: PartitionLayout,
        next_id: u64,
    ) -> Self {
        let s = Self::from_scheds(scheds, layout);
        s.next_id.store(next_id.max(1), Ordering::SeqCst);
        s
    }

    fn from_scheds(
        scheds: Vec<(PartitionId, &'static str, Scheduler)>,
        layout: PartitionLayout,
    ) -> Self {
        let shards = scheds
            .into_iter()
            .map(|(partition, label, sched)| {
                let snapshot = Arc::new(SchedSnapshot::capture(&sched, None));
                ShardSlot {
                    partition,
                    label,
                    sched: Mutex::new(sched),
                    snapshot: RwLock::new(snapshot),
                    locks: AtomicU64::new(0),
                    lock_hold: Mutex::new(LogHistogram::default()),
                }
            })
            .collect();
        Self {
            shards,
            next_id: AtomicU64::new(1),
            epoch: AtomicU64::new(0),
            layout,
        }
    }

    /// Number of scheduler shards.
    pub fn count(&self) -> usize {
        self.shards.len()
    }

    /// More than one shard?
    pub fn is_sharded(&self) -> bool {
        self.shards.len() > 1
    }

    /// The partition a shard owns (sharded mode: shard index ↔ partition).
    pub fn partition(&self, idx: usize) -> PartitionId {
        self.shards[idx].partition
    }

    /// The shard a submission of this QoS routes to.
    pub fn shard_for(&self, qos: QosClass) -> usize {
        if !self.is_sharded() {
            return 0;
        }
        let target = self.layout.route(qos);
        self.shards
            .iter()
            .position(|s| s.partition == target)
            .unwrap_or(0)
    }

    /// Lock one shard's scheduler and count the acquisition. The caller
    /// times the hold and reports it via [`SchedShards::record_hold`].
    pub fn lock(&self, idx: usize) -> MutexGuard<'_, Scheduler> {
        self.shards[idx].locks.fetch_add(1, Ordering::Relaxed);
        self.shards[idx].sched.lock().expect("shard scheduler poisoned")
    }

    /// Record one lock hold on shard `idx` (ns).
    pub fn record_hold(&self, idx: usize, hold_ns: u64) {
        self.shards[idx]
            .lock_hold
            .lock()
            .expect("shard metrics poisoned")
            .record(hold_ns);
    }

    /// Reserve `n` globally-unique, contiguous job ids (sharded mode).
    /// Must be called with the target shard's mutex held — that is what
    /// keeps a shard's internal counter from running ahead of the global
    /// allocator (the reservation is applied with `force_next_id` before
    /// any other reservation against the same shard can land).
    pub fn allocate_ids(&self, n: u64) -> u64 {
        self.next_id.fetch_add(n, Ordering::SeqCst)
    }

    /// The global id watermark (next id to be allocated). Sharded mode
    /// only; feeds the merged snapshot's signature.
    pub fn next_id(&self) -> u64 {
        self.next_id.load(Ordering::SeqCst)
    }

    /// Capture shard `idx`'s snapshot under its (held) mutex, delta-shared
    /// with the previous slot value, and store it.
    pub fn store_snapshot(&self, idx: usize, sched: &Scheduler) {
        let slot = &self.shards[idx];
        let prev = Arc::clone(&slot.snapshot.read().expect("shard snapshot poisoned"));
        let next = Arc::new(SchedSnapshot::capture(sched, Some(&prev)));
        *slot.snapshot.write().expect("shard snapshot poisoned") = next;
    }

    /// One shard's latest published snapshot.
    pub fn shard_snapshot(&self, idx: usize) -> Arc<SchedSnapshot> {
        Arc::clone(&self.shards[idx].snapshot.read().expect("shard snapshot poisoned"))
    }

    /// Merge every shard's slot into one global snapshot stamped with the
    /// next epoch. Slots are read lock-free of the shard mutexes; a slot
    /// read concurrently with another shard's publish yields either its
    /// old or new value — both internally consistent — and the epoch
    /// ordering at the swap site keeps the published view monotone.
    pub fn merged_snapshot(&self) -> Arc<SchedSnapshot> {
        let slots: Vec<Arc<SchedSnapshot>> = self
            .shards
            .iter()
            .map(|s| Arc::clone(&s.snapshot.read().expect("shard snapshot poisoned")))
            .collect();
        let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        Arc::new(SchedSnapshot::merged(&slots, epoch, self.next_id()))
    }

    /// Per-shard stat rows (STATS v2 `shard kind=sched` records).
    pub fn stats(&self) -> Vec<SchedShardStat> {
        self.shards
            .iter()
            .enumerate()
            .map(|(index, s)| {
                let hold = s.lock_hold.lock().expect("shard metrics poisoned").clone();
                let snap = s.snapshot.read().expect("shard snapshot poisoned");
                SchedShardStat {
                    index,
                    label: s.label.to_string(),
                    locks: s.locks.load(Ordering::Relaxed),
                    lock_hold_p99_ns: hold.p99(),
                    lock_hold_max_ns: hold.max(),
                    pending: snap.pending,
                    running: snap.running,
                    dispatches: snap.stats.dispatches,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology;
    use crate::job::{JobSpec, JobType, UserId};
    use crate::sim::{SchedCosts, SimTime};

    fn cfg() -> SchedulerConfig {
        SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
    }

    #[test]
    fn single_mode_is_one_shard_over_the_whole_cluster() {
        let s = SchedShards::single(topology::tx2500(), cfg());
        assert_eq!(s.count(), 1);
        assert!(!s.is_sharded());
        assert_eq!(s.shard_for(QosClass::Normal), 0);
        assert_eq!(s.shard_for(QosClass::Spot), 0);
        let total = s.lock(0).cluster().total_cores();
        assert_eq!(total, topology::tx2500().total_cores());
    }

    #[test]
    fn sharded_dual_splits_nodes_and_routes_by_qos() {
        let full = topology::tx2500();
        let (nodes, cores) = (full.node_count(), full.cores_per_node());
        let s = SchedShards::sharded(full, cfg(), 2);
        assert_eq!(s.count(), 2);
        assert!(s.is_sharded());
        assert_eq!(s.shard_for(QosClass::Normal), 0, "interactive → shard 0");
        assert_eq!(s.shard_for(QosClass::Spot), 1, "spot → shard 1");
        let n0 = s.lock(0).cluster().node_count();
        let n1 = s.lock(1).cluster().node_count();
        assert_eq!(n0 + n1, nodes, "shards cover the whole node pool");
        assert!(n0.abs_diff(n1) <= 1, "split is proportional");
        assert_eq!(s.lock(0).cluster().cores_per_node(), cores);
    }

    #[test]
    fn oversized_or_degenerate_counts_fall_back_to_single() {
        // More shards than partitions: clamped to the partition count.
        assert_eq!(SchedShards::sharded(topology::tx2500(), cfg(), 8).count(), 2);
        // Single-partition layout cannot shard.
        let single_cfg =
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Single);
        assert_eq!(SchedShards::sharded(topology::tx2500(), single_cfg, 4).count(), 1);
        // count <= 1 is the unsharded daemon.
        assert_eq!(SchedShards::sharded(topology::tx2500(), cfg(), 1).count(), 1);
        // A one-node cluster cannot give two shards a node each.
        let tiny = Cluster::homogeneous(1, 32);
        assert_eq!(SchedShards::sharded(tiny, cfg(), 2).count(), 1);
    }

    #[test]
    fn global_ids_stay_unique_and_contiguous_across_shards() {
        let s = SchedShards::sharded(topology::tx2500(), cfg(), 2);
        // Interleave allocations against both shards, the way concurrent
        // SUBMITs land.
        let mut all = Vec::new();
        for round in 0..3 {
            for idx in 0..2 {
                let mut sched = s.lock(idx);
                let first = s.allocate_ids(2);
                sched.force_next_id(first);
                let spec = if idx == 0 {
                    JobSpec::interactive(UserId(round), JobType::TripleMode, 32)
                } else {
                    JobSpec::spot(UserId(9), JobType::Array, 16)
                };
                let ids = sched.submit_batch(vec![spec.clone(), spec]);
                assert_eq!(ids[0].0, first, "reservation is the assignment");
                assert_eq!(ids[1].0, first + 1, "reservation is contiguous");
                all.extend(ids.into_iter().map(|j| j.0));
            }
        }
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len(), "ids are globally unique");
        assert_eq!(sorted, (1..=12).collect::<Vec<u64>>(), "no holes");
        assert_eq!(s.next_id(), 13);
    }

    #[test]
    fn merged_snapshot_covers_both_shards_with_monotone_epochs() {
        let s = SchedShards::sharded(topology::tx2500(), cfg(), 2);
        {
            let mut sched = s.lock(0);
            let first = s.allocate_ids(1);
            sched.force_next_id(first);
            sched.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 32));
            sched.run_until(SimTime::from_secs(30));
            s.store_snapshot(0, &sched);
        }
        {
            let mut sched = s.lock(1);
            let first = s.allocate_ids(1);
            sched.force_next_id(first);
            sched.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
            sched.run_until(SimTime::from_secs(30));
            s.store_snapshot(1, &sched);
        }
        let m1 = s.merged_snapshot();
        assert_eq!(m1.jobs().len(), 2, "both shards' jobs visible");
        assert!(m1.job(1).is_some() && m1.job(2).is_some());
        let m2 = s.merged_snapshot();
        assert!(m2.version > m1.version, "epochs are monotone");
        // Occupancy sums to the full pool.
        assert_eq!(
            m1.cluster.total_cores,
            topology::tx2500().total_cores(),
            "merged occupancy covers the whole cluster"
        );
    }

    #[test]
    fn shard_plan_is_deterministic_and_feeds_recovery() {
        let full = topology::tx2500();
        let p1 = shard_plan(&full, &cfg(), 2);
        let p2 = shard_plan(&full, &cfg(), 2);
        assert_eq!(p1.len(), 2);
        for ((id_a, name_a, c_a), (id_b, name_b, c_b)) in p1.iter().zip(&p2) {
            assert_eq!(id_a, id_b);
            assert_eq!(name_a, name_b);
            assert_eq!(c_a.node_count(), c_b.node_count(), "slices reproduce");
        }
        // Degenerate plans collapse to one whole-cluster entry.
        assert_eq!(shard_plan(&Cluster::homogeneous(1, 32), &cfg(), 2).len(), 1);
        // The recovery constructor re-seats the global allocator.
        let layout = cfg().layout;
        let scheds = p1
            .into_iter()
            .map(|(id, name, slice)| (id, name, Scheduler::new(slice, cfg())))
            .collect();
        let s = SchedShards::sharded_from(scheds, layout, 57);
        assert_eq!(s.count(), 2);
        assert_eq!(s.next_id(), 57);
        assert_eq!(s.allocate_ids(3), 57, "allocation continues at the watermark");
    }

    #[test]
    fn shard_stats_report_locks_and_depth() {
        let s = SchedShards::sharded(topology::tx2500(), cfg(), 2);
        {
            let mut sched = s.lock(1);
            let first = s.allocate_ids(1);
            sched.force_next_id(first);
            sched.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
            s.store_snapshot(1, &sched);
        }
        s.record_hold(1, 5_000);
        let rows = s.stats();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].label, "interactive");
        assert_eq!(rows[1].label, "spot");
        assert_eq!(rows[1].locks, 1);
        assert_eq!(rows[1].pending, 1, "queue depth from the shard snapshot");
        assert!(rows[1].lock_hold_max_ns >= 5_000);
        assert_eq!(rows[0].locks, 0, "untouched shard records nothing");
    }
}
