//! The daemon's published read view and the WAIT subscription hub.
//!
//! After every mutation (submit, cancel, pace) the daemon captures an
//! immutable [`SchedSnapshot`] — job table, queue/occupancy summary,
//! scheduler counters — and swaps it behind `RwLock<Arc<SchedSnapshot>>`.
//! Read-only requests (`SQUEUE` / `SJOB` / `STATS` / `UTIL`) clone the `Arc`
//! and never touch the scheduler mutex, so thousands of status queries per
//! second cannot serialize behind the dispatch path (the contention the
//! companion MIT SuperCloud paper calls out for interactive launch).
//!
//! Capture is incremental at two levels. The scheduler's
//! [`crate::sched::Scheduler::change_version`] tick tells the daemon whether
//! anything externally visible changed since the previous snapshot; when it
//! didn't, the new snapshot shares the previous job table `Arc` and only the
//! virtual clock is refreshed. When the job table *did* move, capture is
//! **delta-based**: each [`JobView`] carries the job's per-record transition
//! counter ([`crate::job::Job::revision`]), and a merge walk over the
//! id-sorted tables re-uses the previous snapshot's `Arc<JobView>` for every
//! job whose revision is unchanged — only actually-mutated jobs pay the
//! event-log lookups and view construction. Combined with terminal-job
//! retirement ([`crate::sched::Scheduler::retire_terminal`], driven by the
//! daemon's grace period), publish cost is bounded by the *live* job set,
//! not the daemon's full history.
//!
//! [`WaitHub`] is the blocked-`WAIT` subscription registry: waiters park on
//! a `Condvar` keyed by a completion generation that the publish path bumps
//! whenever dispatch or terminal progress lands (`DispatchDone` /
//! `Ended` deltas), so a waiter wakes promptly on the event it cares about
//! instead of polling the scheduler lock.

use crate::job::{Job, JobState, JobType, QosClass};
use crate::sched::{EventLog, LogKind, SchedStats, Scheduler};
use crate::sim::SimTime;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Immutable per-job view: everything `SQUEUE` and `SJOB` report, captured
/// at publish time.
#[derive(Debug, Clone, PartialEq)]
pub struct JobView {
    /// Job id.
    pub id: u64,
    /// Launch type.
    pub job_type: JobType,
    /// Task count.
    pub tasks: u32,
    /// Owning user.
    pub user: u32,
    /// QoS class.
    pub qos: QosClass,
    /// Lifecycle state.
    pub state: JobState,
    /// Submission time (virtual seconds).
    pub submit_secs: f64,
    /// Last time the job (re-)entered the pending queue.
    pub queue_secs: f64,
    /// Last start time.
    pub start_secs: Option<f64>,
    /// Terminal time.
    pub end_secs: Option<f64>,
    /// Preempt+requeue count.
    pub requeues: u32,
    /// First `Recognized` event-log time.
    pub recognized: Option<SimTime>,
    /// Last `DispatchDone` event-log time.
    pub dispatched: Option<SimTime>,
    /// Job tag (shared with the spec: capture costs one `Arc` clone).
    pub tag: Arc<str>,
    /// The job's transition counter at capture: delta capture re-uses the
    /// previous snapshot's view whenever this is unchanged.
    pub revision: u64,
}

impl JobView {
    /// Build the view of one job record (shared by snapshot capture and the
    /// daemon's retirement path).
    pub(crate) fn of(j: &Job, log: &EventLog) -> JobView {
        JobView {
            id: j.id.0,
            job_type: j.spec.job_type,
            tasks: j.spec.tasks,
            user: j.spec.user.0,
            qos: j.spec.qos,
            state: j.state,
            submit_secs: j.submit_time.as_secs_f64(),
            queue_secs: j.queue_time.as_secs_f64(),
            start_secs: j.start_time.map(SimTime::as_secs_f64),
            end_secs: j.end_time.map(SimTime::as_secs_f64),
            requeues: j.requeue_count,
            recognized: log.first(j.id, LogKind::Recognized),
            dispatched: log.last(j.id, LogKind::DispatchDone),
            tag: Arc::clone(&j.spec.tag),
            revision: j.revision(),
        }
    }

    /// Virtual scheduling latency (recognized → dispatched) in ns.
    pub fn latency_ns(&self) -> Option<u64> {
        match (self.recognized, self.dispatched) {
            (Some(r), Some(d)) => Some(d.saturating_sub(r).as_nanos()),
            _ => None,
        }
    }

    /// A `WAIT` on this job can stop: it dispatched, or a terminal state
    /// makes dispatch impossible.
    pub fn settled(&self) -> bool {
        self.dispatched.is_some() || self.state.is_terminal()
    }
}

/// Cluster occupancy at capture time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterView {
    /// Allocated-core fraction.
    pub utilization: f64,
    /// Idle cores.
    pub idle_cores: u32,
    /// Fully-idle nodes.
    pub idle_nodes: u32,
    /// Total cores.
    pub total_cores: u32,
}

/// What a `WAIT` can learn from one snapshot about a set of job ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitView {
    /// Jobs whose `DispatchDone` record exists.
    pub dispatched: u32,
    /// Every job either dispatched or can never dispatch.
    pub settled: bool,
    /// Burst virtual scheduling latency (first recognized → last
    /// dispatched), 0 until at least one job dispatched.
    pub latency_ns: u64,
}

/// An immutable view of the scheduler, published after each mutation.
#[derive(Debug, Clone)]
pub struct SchedSnapshot {
    /// Virtual time at capture.
    pub virtual_now: SimTime,
    /// The scheduler change tick this snapshot reflects.
    pub version: u64,
    /// The job-table signature the `jobs` table reflects (gates rebuilds).
    jobs_sig: (usize, u64, u64, u64),
    /// Scheduler counters.
    pub stats: SchedStats,
    /// Priority scorer backend name.
    pub scorer: Arc<str>,
    /// Cluster occupancy.
    pub cluster: ClusterView,
    /// Pending-job count.
    pub pending: usize,
    /// Running-job count.
    pub running: usize,
    /// Terminal transitions so far (`Ended` log records) — with
    /// `stats.dispatches`, the completion generation WAIT subscribers key on.
    pub ended: usize,
    /// Distinct (qos, user) fairshare entries with nonzero charged usage.
    /// Read from the scheduler's incrementally maintained tables at capture
    /// (O(partitions), never a per-user walk), so publishing stays O(1) in
    /// user cardinality.
    pub users_active: usize,
    /// `users_active` plus live pending-queue (qos, user) buckets — the
    /// total per-user state the scheduler is holding right now.
    pub users_tracked: usize,
    /// Job table, ascending id order. The outer `Arc` is shared with the
    /// previous snapshot whenever [`Scheduler::jobs_signature`] says no job
    /// changed; the per-job `Arc<JobView>`s are shared for every job whose
    /// revision is unchanged (delta capture).
    jobs: Arc<Vec<Arc<JobView>>>,
}

impl SchedSnapshot {
    /// Capture the scheduler's externally visible state. Pass the previous
    /// snapshot so unchanged parts are shared, not rebuilt: the clock,
    /// counters, and cluster occupancy refresh on every capture (cheap);
    /// the whole table `Arc` is shared when the job-table signature is
    /// unmoved; and when it did move, a merge walk re-uses every previous
    /// `Arc<JobView>` whose per-job revision is unchanged — only mutated
    /// jobs pay event-log lookups and view construction.
    pub fn capture(sched: &Scheduler, prev: Option<&SchedSnapshot>) -> SchedSnapshot {
        let version = sched.change_version();
        if let Some(p) = prev {
            if p.version == version {
                let mut next = p.clone();
                next.virtual_now = sched.now();
                return next;
            }
        }
        let jobs_sig = sched.jobs_signature();
        let c = sched.cluster();
        let cluster = ClusterView {
            utilization: c.utilization(),
            idle_cores: c.idle_cores(),
            idle_nodes: c.idle_node_count(),
            total_cores: c.total_cores(),
        };
        let (users_active, users_tracked) = sched.user_scale();
        if let Some(p) = prev {
            if p.jobs_sig == jobs_sig {
                return SchedSnapshot {
                    virtual_now: sched.now(),
                    version,
                    jobs_sig,
                    stats: sched.stats().clone(),
                    scorer: Arc::clone(&p.scorer),
                    cluster,
                    pending: p.pending,
                    running: p.running,
                    ended: p.ended,
                    users_active,
                    users_tracked,
                    jobs: Arc::clone(&p.jobs),
                };
            }
        }
        let log = sched.log();
        // Delta merge: both tables are id-sorted; ids present in prev but
        // not in the scheduler were retired and simply drop out.
        let prev_jobs: &[Arc<JobView>] = prev.map_or(&[], |p| p.jobs.as_slice());
        let mut pi = 0usize;
        let mut jobs: Vec<Arc<JobView>> = Vec::with_capacity(prev_jobs.len() + 8);
        let (mut pending, mut running) = (0usize, 0usize);
        for j in sched.jobs() {
            while pi < prev_jobs.len() && prev_jobs[pi].id < j.id.0 {
                pi += 1;
            }
            let v = if pi < prev_jobs.len()
                && prev_jobs[pi].id == j.id.0
                && prev_jobs[pi].revision == j.revision()
            {
                Arc::clone(&prev_jobs[pi])
            } else {
                Arc::new(JobView::of(j, log))
            };
            match v.state {
                JobState::Pending => pending += 1,
                JobState::Running => running += 1,
                _ => {}
            }
            jobs.push(v);
        }
        SchedSnapshot {
            virtual_now: sched.now(),
            version,
            jobs_sig,
            stats: sched.stats().clone(),
            scorer: Arc::from(sched.config().scorer.name()),
            cluster,
            pending,
            running,
            ended: log.count(LogKind::Ended),
            users_active,
            users_tracked,
            jobs: Arc::new(jobs),
        }
    }

    /// Merge per-shard snapshots into one coherent global view — the
    /// sharded coordinator's epoch publish path. Each input is internally
    /// consistent (captured under its own shard's mutex), and job ids are
    /// globally unique (the shard layer allocates every id from one global
    /// counter), so an id-ordered k-way merge of the shard tables yields
    /// one id-sorted global table; the per-job `Arc<JobView>`s are shared
    /// with the shard snapshots, so the merge allocates one `Vec`, not new
    /// views. `epoch` is the cross-shard publish sequence (monotone over
    /// every shard's publishes — it plays the role a single scheduler's
    /// `change_version` plays in the unsharded daemon) and `next_id` is the
    /// global allocator watermark; readers therefore observe a version and
    /// signature that move exactly when any shard moved.
    pub(crate) fn merged(
        shards: &[Arc<SchedSnapshot>],
        epoch: u64,
        next_id: u64,
    ) -> SchedSnapshot {
        assert!(!shards.is_empty(), "merged() needs at least one shard");
        let mut stats = SchedStats::default();
        let (mut idle_cores, mut idle_nodes, mut total_cores) = (0u32, 0u32, 0u32);
        let (mut pending, mut running, mut ended) = (0usize, 0usize, 0usize);
        let (mut users_active, mut users_tracked) = (0usize, 0usize);
        let (mut sig_len, mut sig_log, mut sig_resumes) = (0usize, 0u64, 0u64);
        let mut virtual_now = SimTime::ZERO;
        for s in shards.iter().map(Arc::as_ref) {
            stats.main_passes += s.stats.main_passes;
            stats.backfill_passes += s.stats.backfill_passes;
            stats.triggered_passes += s.stats.triggered_passes;
            stats.dispatches += s.stats.dispatches;
            stats.preemptions += s.stats.preemptions;
            stats.requeues += s.stats.requeues;
            stats.cron_passes += s.stats.cron_passes;
            stats.score_batches += s.stats.score_batches;
            stats.jobs_scored += s.stats.jobs_scored;
            idle_cores += s.cluster.idle_cores;
            idle_nodes += s.cluster.idle_nodes;
            total_cores += s.cluster.total_cores;
            pending += s.pending;
            running += s.running;
            ended += s.ended;
            users_active += s.users_active;
            users_tracked += s.users_tracked;
            sig_len += s.jobs_sig.0;
            sig_log += s.jobs_sig.2;
            sig_resumes += s.jobs_sig.3;
            virtual_now = virtual_now.max(s.virtual_now);
        }
        let utilization = if total_cores == 0 {
            0.0
        } else {
            1.0 - f64::from(idle_cores) / f64::from(total_cores)
        };
        let jobs = if shards.len() == 1 {
            Arc::clone(&shards[0].jobs)
        } else {
            let mut cursors: Vec<(usize, &[Arc<JobView>])> = shards
                .iter()
                .map(|s| (0usize, s.jobs.as_slice()))
                .collect();
            let mut out: Vec<Arc<JobView>> = Vec::with_capacity(sig_len);
            loop {
                let mut best: Option<(usize, u64)> = None;
                for (k, &(i, table)) in cursors.iter().enumerate() {
                    if i < table.len() {
                        let id = table[i].id;
                        if best.map_or(true, |(_, bid)| id < bid) {
                            best = Some((k, id));
                        }
                    }
                }
                let Some((k, _)) = best else { break };
                let (i, table) = cursors[k];
                out.push(Arc::clone(&table[i]));
                cursors[k].0 += 1;
            }
            Arc::new(out)
        };
        SchedSnapshot {
            virtual_now,
            version: epoch,
            jobs_sig: (sig_len, next_id, sig_log, sig_resumes),
            stats,
            scorer: Arc::clone(&shards[0].scorer),
            cluster: ClusterView {
                utilization,
                idle_cores,
                idle_nodes,
                total_cores,
            },
            pending,
            running,
            ended,
            users_active,
            users_tracked,
            jobs,
        }
    }

    /// The job table, ascending id order.
    pub fn jobs(&self) -> &[Arc<JobView>] {
        &self.jobs
    }

    /// One job's view (binary search — the table is id-sorted).
    pub fn job(&self, id: u64) -> Option<&JobView> {
        self.jobs
            .binary_search_by_key(&id, |v| v.id)
            .ok()
            .map(|i| self.jobs[i].as_ref())
    }

    /// Jobs in one state, ascending id order.
    pub fn jobs_in_state(&self, state: JobState) -> impl Iterator<Item = &JobView> {
        self.jobs
            .iter()
            .map(Arc::as_ref)
            .filter(move |v| v.state == state)
    }

    /// Evaluate a `WAIT` against this snapshot. Unknown ids count as
    /// settled (they can never dispatch); existence is checked once at
    /// `WAIT` admission, not here. The daemon evaluates through
    /// [`wait_view_of`] with its history side-table folded in, so retired
    /// jobs keep reporting their dispatch.
    pub fn wait_view(&self, ids: &[u64]) -> WaitView {
        wait_view_of(ids.iter().map(|&id| self.job(id)))
    }
}

/// Aggregate a `WAIT` view over per-id view lookups (`None` = unknown,
/// counted as settled). Shared by the snapshot-only evaluation above and
/// the daemon's snapshot+history evaluation.
pub(crate) fn wait_view_of<'a>(views: impl Iterator<Item = Option<&'a JobView>>) -> WaitView {
    let mut first_recognized: Option<SimTime> = None;
    let mut last_dispatched: Option<SimTime> = None;
    let mut dispatched = 0u32;
    let mut settled = true;
    for view in views {
        let Some(v) = view else { continue };
        if let Some(r) = v.recognized {
            first_recognized = Some(first_recognized.map_or(r, |c| c.min(r)));
        }
        if let Some(d) = v.dispatched {
            dispatched += 1;
            last_dispatched = Some(last_dispatched.map_or(d, |c| c.max(d)));
        } else if !v.state.is_terminal() {
            settled = false;
        }
    }
    let latency_ns = match (first_recognized, last_dispatched) {
        (Some(r), Some(d)) => d.saturating_sub(r).as_nanos(),
        _ => 0,
    };
    WaitView {
        dispatched,
        settled,
        latency_ns,
    }
}

/// The blocked-`WAIT` subscription hub: a completion generation behind a
/// `Condvar`. The publish path bumps it when dispatch/terminal progress
/// lands; waiters park until the generation moves (or a timeout expires)
/// and then re-check the latest snapshot. Reading the generation *before*
/// checking the snapshot makes the protocol lose-free: any publish between
/// the check and the park moves the generation, so the park returns
/// immediately.
///
/// Besides condvar waiters, the hub carries **wakers**: registered
/// callbacks invoked on every [`WaitHub::notify`]. The Linux connection
/// reactor subscribes one that writes its eventfd, so a completion notify
/// wakes `epoll_wait` directly — no dedicated waiter thread sits between
/// the publish path and the parked connections. Wakers must be cheap and
/// lock-free (`notify` runs on the publish path, often with the scheduler
/// mutex held by the caller).
#[derive(Default)]
pub struct WaitHub {
    generation: Mutex<u64>,
    cv: Condvar,
    wakers: Mutex<Vec<(u64, Box<dyn Fn() + Send + Sync>)>>,
    next_waker: Mutex<u64>,
}

impl WaitHub {
    /// Current completion generation.
    pub fn generation(&self) -> u64 {
        *self.generation.lock().expect("wait hub poisoned")
    }

    /// Register a waker invoked on every notify. Returns an id for
    /// [`WaitHub::unsubscribe`].
    pub fn subscribe(&self, f: Box<dyn Fn() + Send + Sync>) -> u64 {
        let mut next = self.next_waker.lock().expect("wait hub poisoned");
        let id = *next;
        *next += 1;
        drop(next);
        self.wakers.lock().expect("wait hub poisoned").push((id, f));
        id
    }

    /// Remove a waker registered with [`WaitHub::subscribe`].
    pub fn unsubscribe(&self, id: u64) {
        self.wakers
            .lock()
            .expect("wait hub poisoned")
            .retain(|(wid, _)| *wid != id);
    }

    /// Announce progress: bump the generation and wake every parked waiter
    /// and registered waker.
    pub fn notify(&self) {
        let mut g = self.generation.lock().expect("wait hub poisoned");
        *g = g.wrapping_add(1);
        self.cv.notify_all();
        drop(g);
        for (_, waker) in self.wakers.lock().expect("wait hub poisoned").iter() {
            waker();
        }
    }

    /// Park until the generation moves past `seen` or `timeout` elapses.
    /// Returns the generation observed on wake.
    pub fn wait_change(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.generation.lock().expect("wait hub poisoned");
        while *g == seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(g, deadline - now)
                .expect("wait hub poisoned");
            g = guard;
        }
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::job::{JobId, JobSpec, UserId};
    use crate::sched::SchedulerConfig;
    use crate::sim::SchedCosts;

    fn sched() -> Scheduler {
        Scheduler::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        )
    }

    #[test]
    fn capture_reflects_jobs_and_states() {
        let mut s = sched();
        let id = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        let snap = SchedSnapshot::capture(&s, None);
        let v = snap.job(id.0).expect("submitted job visible");
        assert_eq!(v.state, JobState::Pending);
        assert_eq!(&*v.tag, "interactive", "tag flows into the published view");
        assert!(!v.settled());
        assert!(s.run_until_dispatched(&[id], SimTime::from_secs(60)));
        let snap2 = SchedSnapshot::capture(&s, Some(&snap));
        let v2 = snap2.job(id.0).unwrap();
        assert_eq!(v2.state, JobState::Running);
        assert!(v2.settled());
        assert!(v2.latency_ns().unwrap() > 0);
        assert_eq!(snap2.running, 1);
        assert_eq!(snap2.pending, 0);
    }

    #[test]
    fn unchanged_version_shares_the_job_table() {
        let mut s = sched();
        s.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        let a = SchedSnapshot::capture(&s, None);
        // No mutation in between: the table must be shared, not rebuilt.
        let b = SchedSnapshot::capture(&s, Some(&a));
        assert!(Arc::ptr_eq(&a.jobs, &b.jobs));
        // A mutation forces a rebuild.
        s.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        let c = SchedSnapshot::capture(&s, Some(&b));
        assert!(!Arc::ptr_eq(&b.jobs, &c.jobs));
        assert_eq!(c.jobs().len(), 2);
    }

    #[test]
    fn counters_refresh_without_table_rebuild() {
        // Periodic cycles bump the change tick (pass counters move) but do
        // not touch any job: the O(jobs) table must be shared, only the
        // cheap header rebuilt.
        let mut s = sched();
        let a = SchedSnapshot::capture(&s, None);
        s.run_until(SimTime::from_secs(60)); // several main/backfill passes
        let b = SchedSnapshot::capture(&s, Some(&a));
        assert!(b.stats.main_passes > a.stats.main_passes, "{:?}", b.stats);
        assert_ne!(a.version, b.version);
        assert!(Arc::ptr_eq(&a.jobs, &b.jobs), "empty table was rebuilt");
    }

    #[test]
    fn delta_capture_shares_unchanged_job_views() {
        let mut s = sched();
        let a = s.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        let b = s.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        assert!(s.run_until_dispatched(&[a, b], SimTime::from_secs(60)));
        let snap1 = SchedSnapshot::capture(&s, None);
        // Cancel only b: the rebuilt table must re-use a's view allocation
        // and rebuild b's.
        assert!(s.cancel(JobId(b.0)));
        let snap2 = SchedSnapshot::capture(&s, Some(&snap1));
        assert!(!Arc::ptr_eq(&snap1.jobs, &snap2.jobs), "table must rebuild");
        let va1 = &snap1.jobs()[0];
        let va2 = &snap2.jobs()[0];
        assert_eq!(va1.id, a.0);
        assert!(Arc::ptr_eq(va1, va2), "unchanged job must share its JobView");
        let vb1 = &snap1.jobs()[1];
        let vb2 = &snap2.jobs()[1];
        assert!(!Arc::ptr_eq(vb1, vb2), "cancelled job must get a fresh view");
        assert_eq!(vb2.state, JobState::Cancelled);
    }

    #[test]
    fn retired_jobs_drop_out_of_the_delta_merge() {
        let mut s = sched();
        let a = s.submit(
            JobSpec::interactive(UserId(1), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(1)),
        );
        let b = s.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        assert!(s.run_until_dispatched(&[a, b], SimTime::from_secs(60)));
        s.run_for(SimTime::from_secs(120)); // a completes; b keeps running
        let snap1 = SchedSnapshot::capture(&s, None);
        assert!(snap1.job(a.0).is_some());
        assert_eq!(s.retire_terminal(SimTime::from_secs(10)).len(), 1);
        let snap2 = SchedSnapshot::capture(&s, Some(&snap1));
        assert!(snap2.job(a.0).is_none(), "retired job leaves the table");
        let vb = snap2.job(b.0).expect("running job stays");
        // The survivor's view is still the shared allocation from snap1.
        assert!(Arc::ptr_eq(&snap1.jobs()[1], &snap2.jobs()[0]));
        assert_eq!(vb.state, JobState::Running);
    }

    #[test]
    fn jobs_signature_honest_under_suspend_resume() {
        use crate::preempt::{PreemptApproach, PreemptMode};
        let cfg = crate::sched::SchedulerConfig::baseline(
            SchedCosts::dedicated(),
            PartitionLayout::Dual,
        )
        .with_approach(PreemptApproach::AutoScheduler {
            mode: PreemptMode::Suspend,
        });
        let mut s = Scheduler::new(topology::tx2500(), cfg);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        let snap_running = SchedSnapshot::capture(&s, None);
        // Suspend via auto preemption.
        let inter = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        s.run_for(SimTime::from_secs(60));
        assert_eq!(s.job(spot).unwrap().state, JobState::Suspended);
        let sig_suspended = s.jobs_signature();
        let snap_suspended = SchedSnapshot::capture(&s, Some(&snap_running));
        assert_eq!(snap_suspended.job(spot.0).unwrap().state, JobState::Suspended);
        // Resume (cancel the interactive demand): the signature must move
        // even though no log entry or membership change happens, and the
        // suspended job's view must be rebuilt, not shared.
        assert!(s.cancel(inter));
        s.run_for(SimTime::from_secs(120));
        assert_eq!(s.job(spot).unwrap().state, JobState::Running);
        assert_ne!(s.jobs_signature(), sig_suspended, "resume must move the signature");
        let snap_resumed = SchedSnapshot::capture(&s, Some(&snap_suspended));
        assert_eq!(snap_resumed.job(spot.0).unwrap().state, JobState::Running);
    }

    #[test]
    fn merged_snapshot_interleaves_shard_tables_and_sums_counters() {
        let mut a = sched();
        let mut b = sched();
        // Interleave globally-unique ids across the two shards, the way the
        // shard layer's global allocator hands them out.
        a.force_next_id(10);
        let a1 = a.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 32));
        b.force_next_id(11);
        let b1 = b.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        a.force_next_id(12);
        let a2 = a.submit(JobSpec::interactive(UserId(2), JobType::TripleMode, 32));
        assert_eq!((a1.0, b1.0, a2.0), (10, 11, 12));
        assert!(a.run_until_dispatched(&[a1, a2], SimTime::from_secs(60)));
        b.run_until(SimTime::from_secs(60));
        let sa = Arc::new(SchedSnapshot::capture(&a, None));
        let sb = Arc::new(SchedSnapshot::capture(&b, None));
        let m = SchedSnapshot::merged(&[Arc::clone(&sa), Arc::clone(&sb)], 41, 13);
        assert_eq!(m.version, 41, "merged version is the publish epoch");
        let ids: Vec<u64> = m.jobs().iter().map(|v| v.id).collect();
        assert_eq!(ids, vec![10, 11, 12], "k-way merge is id-sorted");
        // Views are shared with the shard snapshots, not rebuilt.
        assert!(Arc::ptr_eq(&m.jobs()[0], &sa.jobs()[0]));
        assert!(Arc::ptr_eq(&m.jobs()[1], &sb.jobs()[0]));
        assert!(Arc::ptr_eq(&m.jobs()[2], &sa.jobs()[1]));
        // Counters and occupancy are sums across shards.
        assert_eq!(m.running, sa.running + sb.running);
        assert_eq!(m.pending, sa.pending + sb.pending);
        assert_eq!(m.ended, sa.ended + sb.ended);
        assert_eq!(
            m.stats.dispatches,
            sa.stats.dispatches + sb.stats.dispatches
        );
        assert_eq!(
            m.cluster.total_cores,
            sa.cluster.total_cores + sb.cluster.total_cores
        );
        assert_eq!(
            m.cluster.idle_cores,
            sa.cluster.idle_cores + sb.cluster.idle_cores
        );
        assert_eq!(m.virtual_now, SimTime::from_secs(60));
        // The merged table answers point lookups like any snapshot.
        assert_eq!(m.job(11).unwrap().qos, QosClass::Spot);
        let wv = m.wait_view(&[10, 12]);
        assert_eq!(wv.dispatched, 2);
        assert!(wv.settled);
    }

    #[test]
    fn merged_single_shard_shares_the_table_arc() {
        let mut s = sched();
        s.submit(JobSpec::spot(UserId(9), JobType::Array, 16));
        let snap = Arc::new(SchedSnapshot::capture(&s, None));
        let m = SchedSnapshot::merged(&[Arc::clone(&snap)], 7, 2);
        assert!(Arc::ptr_eq(&m.jobs, &snap.jobs), "one shard: no copy");
        assert_eq!(m.version, 7);
        assert_eq!(m.pending, snap.pending);
    }

    #[test]
    fn wait_view_partial_and_settled() {
        let mut s = sched();
        let a = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 32));
        let b = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 32));
        assert!(s.run_until_dispatched(&[a], SimTime::from_secs(60)));
        let snap = SchedSnapshot::capture(&s, None);
        let wv = snap.wait_view(&[a.0, b.0]);
        // Both dispatch in the same pass unless resources block; accept
        // either, but the view must be internally consistent.
        assert!(wv.dispatched >= 1);
        assert_eq!(wv.settled, wv.dispatched == 2);
        assert!(wv.latency_ns > 0);
        assert!(s.cancel(JobId(b.0)) || wv.dispatched == 2);
        let snap2 = SchedSnapshot::capture(&s, Some(&snap));
        assert!(snap2.wait_view(&[a.0, b.0]).settled);
    }

    #[test]
    fn wait_view_empty_ids_is_settled() {
        let s = sched();
        let snap = SchedSnapshot::capture(&s, None);
        let wv = snap.wait_view(&[]);
        assert!(wv.settled);
        assert_eq!(wv.dispatched, 0);
        assert_eq!(wv.latency_ns, 0);
    }

    #[test]
    fn hub_wakes_on_notify_and_times_out() {
        let hub = Arc::new(WaitHub::default());
        let seen = hub.generation();
        // Timeout path: no notify, generation unchanged.
        let g = hub.wait_change(seen, Duration::from_millis(20));
        assert_eq!(g, seen);
        // Notify path: a second thread bumps the generation.
        let h2 = Arc::clone(&hub);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            h2.notify();
        });
        let g2 = hub.wait_change(seen, Duration::from_secs(5));
        assert_ne!(g2, seen);
        t.join().unwrap();
        // A stale `seen` returns immediately (lose-free protocol).
        let g3 = hub.wait_change(seen, Duration::from_secs(5));
        assert_eq!(g3, g2);
    }

    #[test]
    fn hub_wakers_fire_per_notify_until_unsubscribed() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hub = WaitHub::default();
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        let id = hub.subscribe(Box::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        hub.notify();
        hub.notify();
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        hub.unsubscribe(id);
        hub.notify();
        assert_eq!(hits.load(Ordering::SeqCst), 2, "unsubscribed waker fired");
    }
}
