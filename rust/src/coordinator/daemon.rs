//! The daemon core: the scheduler as a long-running, thread-safe service.
//!
//! Virtual time advances against the wall clock via a **pacer** thread: every
//! tick it runs the scheduler's event loop up to `elapsed_wall × speedup`.
//! API requests (submit, queue, cancel, stats) lock the scheduler, act, and
//! return. Interactive jobs' virtual scheduling latencies (the paper's
//! metric) are harvested from the event log into the daemon metrics.

use super::api::{self, ApiError, Request};
use super::metrics::DaemonMetrics;
use crate::cluster::Cluster;
use crate::job::{JobId, JobSpec, JobState, QosClass, UserId};
use crate::sched::{LogKind, Scheduler, SchedulerConfig};
use crate::sim::SimTime;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Daemon parameters.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Virtual seconds advanced per wall-clock second (the simulation keeps
    /// up with real submissions at any speedup; 1.0 = real time).
    pub speedup: f64,
    /// Pacer tick in milliseconds.
    pub pacer_tick_ms: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            speedup: 60.0,
            pacer_tick_ms: 5,
        }
    }
}

/// The daemon: shared scheduler + metrics + lifecycle flag.
pub struct Daemon {
    sched: Mutex<Scheduler>,
    /// Daemon metrics (public for the e2e driver's reporting).
    pub metrics: DaemonMetrics,
    running: AtomicBool,
    start: Instant,
    cfg: DaemonConfig,
    tracked: Mutex<BTreeSet<JobId>>,
}

impl Daemon {
    /// Create a daemon over a fresh scheduler.
    pub fn new(cluster: Cluster, sched_cfg: SchedulerConfig, cfg: DaemonConfig) -> Arc<Self> {
        Arc::new(Self {
            sched: Mutex::new(Scheduler::new(cluster, sched_cfg)),
            metrics: DaemonMetrics::default(),
            running: AtomicBool::new(true),
            start: Instant::now(),
            cfg,
            tracked: Mutex::new(BTreeSet::new()),
        })
    }

    /// Still serving?
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Request shutdown.
    pub fn shutdown(&self) {
        self.running.store(false, Ordering::SeqCst);
    }

    /// Target virtual time for the current wall clock.
    fn target_now(&self) -> SimTime {
        SimTime::from_secs_f64(self.start.elapsed().as_secs_f64() * self.cfg.speedup)
    }

    /// Advance the scheduler to the current wall-paced virtual time and
    /// harvest newly dispatched tracked jobs into the metrics.
    pub fn pace(&self) {
        let target = self.target_now();
        let mut sched = self.sched.lock().expect("scheduler poisoned");
        if target > sched.now() {
            sched.run_until(target);
        }
        let mut tracked = self.tracked.lock().expect("tracked poisoned");
        let done: Vec<JobId> = tracked
            .iter()
            .copied()
            .filter(|&j| sched.log().last(j, LogKind::DispatchDone).is_some())
            .collect();
        for j in done {
            tracked.remove(&j);
            let rec = sched.log().first(j, LogKind::Recognized).expect("recognized");
            let dis = sched.log().last(j, LogKind::DispatchDone).expect("dispatched");
            self.metrics.record_sched_latency(dis.saturating_sub(rec).as_nanos());
        }
    }

    /// Spawn the pacer thread. Returns its join handle; the thread exits on
    /// shutdown.
    pub fn spawn_pacer(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let daemon = Arc::clone(self);
        std::thread::Builder::new()
            .name("spotcloud-pacer".into())
            .spawn(move || {
                while daemon.is_running() {
                    daemon.pace();
                    std::thread::sleep(std::time::Duration::from_millis(daemon.cfg.pacer_tick_ms));
                }
            })
            .expect("spawning pacer")
    }

    /// Handle one request line; returns the response body.
    pub fn handle_line(&self, line: &str) -> String {
        let t0 = Instant::now();
        let result = api::parse_request(line).map(|req| self.handle(req));
        let ok = result.is_ok();
        let resp = match result {
            Ok(r) => r,
            Err(e) => api::err(&e),
        };
        self.metrics.record_request(ok, t0.elapsed().as_nanos() as u64);
        resp
    }

    fn handle(&self, req: Request) -> String {
        match req {
            Request::Ping => api::ok("pong"),
            Request::Shutdown => {
                self.shutdown();
                api::ok("shutting down")
            }
            Request::Submit {
                qos,
                job_type,
                tasks,
                user,
                run_secs,
            } => self.handle_submit(qos, job_type, tasks, user, run_secs),
            Request::Scancel(id) => {
                let mut sched = self.sched.lock().expect("scheduler poisoned");
                if sched.cancel(JobId(id)) {
                    api::ok(format!("cancelled {id}"))
                } else {
                    api::err(&ApiError::BadValue {
                        what: "job id",
                        value: id.to_string(),
                    })
                }
            }
            Request::Squeue => {
                let sched = self.sched.lock().expect("scheduler poisoned");
                let mut body = String::from("JOBID TYPE TASKS USER QOS STATE\n");
                let mut shown = 0;
                for st in [JobState::Pending, JobState::Running, JobState::Requeued] {
                    for id in sched.jobs_in_state(st) {
                        let j = sched.job(id).expect("listed job");
                        body.push_str(&format!(
                            "{} {} {} {} {} {:?}\n",
                            id.0,
                            j.spec.job_type.label(),
                            j.spec.tasks,
                            j.spec.user,
                            j.spec.qos,
                            j.state
                        ));
                        shown += 1;
                    }
                }
                body.push_str(&format!("({shown} jobs)"));
                api::ok(format!("\n{body}"))
            }
            Request::Stats => {
                let sched = self.sched.lock().expect("scheduler poisoned");
                let st = sched.stats();
                api::ok(format!(
                    "\nvirtual_now={} dispatches={} preemptions={} requeues={} cron_passes={} \
                     main_passes={} backfill_passes={} triggered_passes={} score_batches={} jobs_scored={} scorer={}\n{}",
                    sched.now(),
                    st.dispatches,
                    st.preemptions,
                    st.requeues,
                    st.cron_passes,
                    st.main_passes,
                    st.backfill_passes,
                    st.triggered_passes,
                    st.score_batches,
                    st.jobs_scored,
                    sched.config().scorer.name(),
                    self.metrics.summary()
                ))
            }
            Request::Util => {
                let sched = self.sched.lock().expect("scheduler poisoned");
                let c = sched.cluster();
                api::ok(format!(
                    "utilization={:.4} idle_cores={} idle_nodes={} total_cores={} pending={} running={}",
                    c.utilization(),
                    c.idle_cores(),
                    c.idle_node_count(),
                    c.total_cores(),
                    sched.jobs_in_state(JobState::Pending).len(),
                    sched.jobs_in_state(JobState::Running).len(),
                ))
            }
        }
    }

    fn handle_submit(
        &self,
        qos: QosClass,
        job_type: crate::job::JobType,
        tasks: u32,
        user: u32,
        run_secs: f64,
    ) -> String {
        let specs: Vec<JobSpec> = match qos {
            QosClass::Normal => crate::workload::interactive_burst(UserId(user), job_type, tasks),
            QosClass::Spot => vec![JobSpec::spot(UserId(user), job_type, tasks)],
        }
        .into_iter()
        .map(|s| s.with_run_time(SimTime::from_secs_f64(run_secs)))
        .collect();

        let mut sched = self.sched.lock().expect("scheduler poisoned");
        // Keep the virtual clock caught up so submissions land "now".
        let target = self.target_now();
        if target > sched.now() {
            sched.run_until(target);
        }
        let ids = sched.submit_burst(specs);
        self.metrics
            .jobs_submitted
            .fetch_add(ids.len() as u64, Ordering::Relaxed);
        if qos == QosClass::Normal {
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            tracked.extend(ids.iter().copied());
        }
        let first = ids.first().map(|j| j.0).unwrap_or(0);
        let last = ids.last().map(|j| j.0).unwrap_or(0);
        api::ok(format!("jobs={first}-{last} count={}", ids.len()))
    }

    /// Lock and inspect the scheduler (tests + e2e reporting).
    pub fn with_scheduler<T>(&self, f: impl FnOnce(&Scheduler) -> T) -> T {
        let sched = self.sched.lock().expect("scheduler poisoned");
        f(&sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::sim::SchedCosts;

    fn daemon() -> Arc<Daemon> {
        Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            DaemonConfig {
                speedup: 10_000.0, // tests shouldn't wait on the wall clock
                pacer_tick_ms: 1,
            },
        )
    }

    #[test]
    fn ping_and_stats() {
        let d = daemon();
        assert_eq!(d.handle_line("PING"), "OK pong");
        assert!(d.handle_line("STATS").contains("virtual_now"));
    }

    #[test]
    fn submit_runs_to_dispatch() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal triple 608 1 60");
        assert!(resp.starts_with("OK jobs="), "{resp}");
        // Pace until dispatch shows up in metrics.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while d.metrics.sched_latency().count() == 0 {
            assert!(Instant::now() < deadline, "job never dispatched");
            d.pace();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        // Baseline triple-mode latency is sub-second of *virtual* time.
        assert!(h.max() < 2_000_000_000, "virtual latency {}ns", h.max());
    }

    #[test]
    fn squeue_lists_jobs() {
        let d = daemon();
        d.handle_line("SUBMIT spot triple 320 9 600");
        let out = d.handle_line("SQUEUE");
        assert!(out.contains("triple-mode 320 user9 spot"), "{out}");
    }

    #[test]
    fn scancel_pending_job() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal array 64 1 600");
        let id: u64 = resp
            .split("jobs=")
            .nth(1)
            .unwrap()
            .split('-')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let out = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out.starts_with("OK cancelled"), "{out}");
        // Cancelling again fails gracefully.
        let out2 = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out2.starts_with("ERR"), "{out2}");
    }

    #[test]
    fn bad_request_counts_as_error() {
        let d = daemon();
        let out = d.handle_line("SUBMIT nope nope nope nope");
        assert!(out.starts_with("ERR"));
        assert_eq!(d.metrics.requests_err.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn util_reports_cluster() {
        let d = daemon();
        let out = d.handle_line("UTIL");
        assert!(out.contains("total_cores=608"), "{out}");
        assert!(out.contains("utilization=0.0000"), "{out}");
    }

    #[test]
    fn shutdown_flips_flag() {
        let d = daemon();
        assert!(d.is_running());
        assert!(d.handle_line("SHUTDOWN").starts_with("OK"));
        assert!(!d.is_running());
    }
}
