//! The daemon core: the scheduler as a long-running, thread-safe service.
//!
//! Virtual time advances against the wall clock via a **pacer** thread: every
//! tick it runs the scheduler's event loop up to `elapsed_wall × speedup`.
//! Interactive jobs' virtual scheduling latencies (the paper's metric) are
//! harvested from the event log into the daemon metrics.
//!
//! Requests split into two paths:
//!
//! * **Write path** (`SUBMIT` / `SCANCEL` / pacing) — takes the scheduler
//!   mutex, mutates, then publishes an immutable [`SchedSnapshot`] behind an
//!   `Arc` swap before releasing it.
//! * **Read path** (`SQUEUE` / `SJOB` / `STATS` / `UTIL`) — clones the
//!   published snapshot `Arc` and never touches the scheduler mutex, so
//!   status queries from thousands of clients cannot serialize behind a
//!   dispatch burst. [`super::metrics::DaemonMetrics`] counts both paths
//!   and histograms the write-lock hold time so a regression is observable.
//!
//! `WAIT` is subscription-based: a request that cannot complete immediately
//! becomes a [`WaitTicket`] parked on the [`WaitHub`] completion generation.
//! In-process callers block on the hub; the TCP server instead detaches the
//! whole connection (see [`super::server`]) — on Linux it stays registered
//! with the epoll reactor, which the hub wakes through an eventfd
//! ([`Daemon::subscribe_completions`]); elsewhere it moves into a waiter
//! registry swept by a notifier thread. Either way, hundreds of concurrent
//! `WAIT`s ride on a handful of worker threads.
//!
//! The daemon works entirely in the typed protocol: [`Daemon::handle`] is
//! `fn(&self, Request) -> Response`; wire rendering lives in
//! [`super::codec`] and is reached through [`Daemon::handle_line_versioned`].

use super::api::{
    ApiError, ContentionStats, ErrorCode, JobDetail, JobSummary, ProtocolVersion, Request,
    Response, ResumeEntry, ResumeInfo, ResumeTarget, SqueueFilter, StatsSnapshot, SubmitAck,
    SubmitSpec, UtilSnapshot, WaitResult,
};
use super::codec;
use super::journal::{
    AdmitEntry, CheckpointJob, CheckpointState, DurabilityConfig, Journal, JournalRecord,
};
use super::manifest::{
    EntryAck, EntryReject, Manifest, ManifestAck, ManifestEntry, ManifestRegistry, ManifestSpan,
    MAX_MANIFEST_ENTRIES,
};
use super::metrics::DaemonMetrics;
use super::recovery::{rebuild, RecoveryError, RecoveryReport};
use super::snapshot::{wait_view_of, JobView, SchedSnapshot, WaitHub, WaitView};
use crate::cluster::Cluster;
use crate::job::{JobId, JobSpec, JobState, QosClass, UserId};
use crate::sched::{LogKind, Scheduler, SchedulerConfig};
use crate::sim::SimTime;
use crate::util::fxhash::FxHashMap;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Upper bound on jobs created by one batched `SUBMIT` (keeps a typo'd
/// `count=` from allocating unbounded scheduler state in one RPC).
pub const MAX_BATCH_JOBS: u64 = 1_000_000;

/// Upper bound on a `WAIT` timeout (wall seconds).
pub const MAX_WAIT_SECS: f64 = 3600.0;

/// How long a parked in-process `WAIT` sleeps between self-pace polls when
/// no completion notify arrives (the hub wakes it earlier on progress).
const WAIT_POLL: Duration = Duration::from_millis(2);

/// Daemon parameters.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Virtual seconds advanced per wall-clock second (the simulation keeps
    /// up with real submissions at any speedup; 1.0 = real time).
    pub speedup: f64,
    /// Pacer tick in milliseconds.
    pub pacer_tick_ms: u64,
    /// Grace period (virtual seconds) a terminal job stays in the
    /// published table before it is retired into the history side-table.
    /// Bounds snapshot publish cost for long-lived daemons: `SQUEUE` stops
    /// listing retired jobs, `SJOB` still answers from history. `None`
    /// never retires.
    pub retire_grace_secs: Option<f64>,
    /// Cap on the retired-job history side-table. Retirement bounds the
    /// *published* table; this bounds the daemon's total memory: past the
    /// cap the oldest retired records are pruned (their event-log entries
    /// went with retirement), and `SJOB`/`WAIT` on a pruned id return the
    /// usual typed `not_found`. `None` keeps history forever.
    pub history_cap: Option<usize>,
    /// Write-ahead journal configuration. `Some` makes every admission and
    /// cancel durable *before* it is acknowledged (see `PROTOCOL.md`
    /// §Durability); `None` keeps the daemon fully in-memory (the seed
    /// behavior).
    pub durability: Option<DurabilityConfig>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            speedup: 60.0,
            pacer_tick_ms: 5,
            retire_grace_secs: Some(3600.0),
            history_cap: Some(100_000),
            durability: None,
        }
    }
}

/// A blocked `WAIT`, waiting for its jobs' completion events.
#[derive(Debug, Clone)]
pub struct WaitTicket {
    /// Job ids the client asked about.
    pub jobs: Vec<u64>,
    /// Wall deadline.
    pub deadline: Instant,
    /// When the request arrived (metrics).
    pub started: Instant,
}

/// Outcome of admitting a `WAIT`: either an immediate response or a parked
/// ticket to poll on completion notifies.
pub enum WaitStart {
    /// Settled (or rejected) without blocking.
    Done(Response),
    /// Parked: poll [`Daemon::poll_wait`] after each completion notify.
    Parked(WaitTicket),
}

/// A parked `WAIT` plus the protocol version its eventual response renders
/// in (what the server's waiter registry holds per connection).
pub struct ParkedWait {
    /// The parked wait.
    pub ticket: WaitTicket,
    /// Render version for the deferred response.
    pub version: ProtocolVersion,
}

/// Outcome of one request line when the caller cannot block (the server's
/// connection loop).
pub enum LineOutcome {
    /// Rendered response and, after a successful `HELLO`, the version the
    /// connection speaks from the next request on.
    Done(String, Option<ProtocolVersion>),
    /// A `WAIT` parked; respond later via [`Daemon::poll_wait`] +
    /// [`Daemon::finish_wait`].
    Parked(ParkedWait),
}

/// The daemon: scheduler write path + published read snapshot + WAIT hub.
pub struct Daemon {
    sched: Mutex<Scheduler>,
    /// The published read view (see [`SchedSnapshot`]). Swapped, never
    /// mutated: readers clone the `Arc` under a momentary read lock.
    snapshot: RwLock<Arc<SchedSnapshot>>,
    hub: WaitHub,
    /// Daemon metrics (public for the e2e driver's reporting).
    pub metrics: DaemonMetrics,
    running: AtomicBool,
    start: Instant,
    /// Virtual time at daemon start (non-zero after recovery: the pacer
    /// resumes from the recovered instant, it never rewinds).
    virtual_base: SimTime,
    cfg: DaemonConfig,
    /// The write-ahead journal, when durability is on. Locked strictly
    /// *inside* the scheduler mutex (admission appends under it, before
    /// the snapshot publish that would make the mutation visible).
    journal: Option<Mutex<Journal>>,
    /// Registered manifests (RESUME / per-entry WAIT lookups). Written on
    /// admission under the scheduler mutex; read lock-free of it.
    manifests: RwLock<ManifestRegistry>,
    tracked: Mutex<BTreeSet<JobId>>,
    /// Retired terminal jobs: frozen views written once at retirement (the
    /// write path, amortized O(1) per job over its lifetime) and read by
    /// `SJOB`/`WAIT` after the job left the published table. Never takes
    /// the scheduler mutex on the read side. Bounded by
    /// [`DaemonConfig::history_cap`]: the oldest retirements are pruned
    /// first (ids retire in end-time order, so eviction follows insertion).
    history: RwLock<HistoryTable>,
}

/// The bounded retired-job side-table: id → frozen view, plus the
/// insertion-order queue the cap evicts from.
#[derive(Default)]
struct HistoryTable {
    views: FxHashMap<u64, Arc<JobView>>,
    order: std::collections::VecDeque<u64>,
}

impl HistoryTable {
    fn get(&self, id: &u64) -> Option<&Arc<JobView>> {
        self.views.get(id)
    }

    fn contains_key(&self, id: &u64) -> bool {
        self.views.contains_key(id)
    }

    /// Insert a retired view, evicting the oldest records past `cap`.
    fn insert_capped(&mut self, id: u64, view: Arc<JobView>, cap: Option<usize>) {
        if self.views.insert(id, view).is_none() {
            self.order.push_back(id);
        }
        if let Some(cap) = cap {
            while self.views.len() > cap.max(1) {
                let Some(oldest) = self.order.pop_front() else { break };
                self.views.remove(&oldest);
            }
        }
    }

    /// Clone the views in insertion (retirement) order — checkpoint
    /// capture, so a recovered daemon rebuilds the same eviction order.
    fn ordered_views(&self) -> Vec<JobView> {
        self.order
            .iter()
            .filter_map(|id| self.views.get(id).map(|v| (**v).clone()))
            .collect()
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.views.len()
    }
}

impl Daemon {
    /// Create a daemon over a fresh scheduler. When durability is
    /// configured this creates a fresh journal and panics if one already
    /// exists or cannot be written — a daemon that silently dropped its
    /// durability guarantee would be worse than one that failed to boot
    /// (use [`Daemon::recover`] on a non-empty journal directory).
    pub fn new(cluster: Cluster, sched_cfg: SchedulerConfig, cfg: DaemonConfig) -> Arc<Self> {
        let sched = Scheduler::new(cluster, sched_cfg);
        let journal = cfg
            .durability
            .as_ref()
            .map(|d| Journal::create(d).expect("creating the write-ahead journal"));
        Self::assemble(sched, cfg, journal, ManifestRegistry::new(), Vec::new())
    }

    /// Recover a daemon from an existing journal: replay the newest
    /// checkpoint plus the tail into a fresh scheduler over
    /// `cluster`/`sched_cfg` (which must match the crashed daemon's), then
    /// resume journaling on the same directory. Running/suspended jobs are
    /// re-queued; interactive jobs that had not yet dispatched are
    /// re-tracked so the latency harvest (and parked-`WAIT` resolution)
    /// picks them up exactly once.
    pub fn recover(
        cluster: Cluster,
        sched_cfg: SchedulerConfig,
        cfg: DaemonConfig,
    ) -> Result<(Arc<Self>, RecoveryReport), RecoveryError> {
        let dcfg = cfg
            .durability
            .as_ref()
            .ok_or_else(|| RecoveryError::Mismatch("recover() without durability config".into()))?;
        let (journal, recovered) = Journal::recover(dcfg)?;
        let rebuilt = rebuild(cluster, sched_cfg, &recovered)?;
        let report = rebuilt.report;
        let daemon = Self::assemble(
            rebuilt.sched,
            cfg,
            Some(journal),
            rebuilt.registry,
            rebuilt.history,
        );
        Ok((daemon, report))
    }

    fn assemble(
        sched: Scheduler,
        cfg: DaemonConfig,
        journal: Option<Journal>,
        registry: ManifestRegistry,
        history_seed: Vec<JobView>,
    ) -> Arc<Self> {
        let virtual_base = sched.now();
        // Re-arm the latency-harvest bookkeeping for interactive jobs that
        // were admitted but had not dispatched when the state was captured
        // (no-op on a fresh scheduler).
        let mut tracked = BTreeSet::new();
        for job in sched.jobs() {
            if job.spec.qos == QosClass::Normal
                && !job.state.is_terminal()
                && sched.log().last(job.id, LogKind::DispatchDone).is_none()
            {
                tracked.insert(job.id);
            }
        }
        // Seed the history table through the same capped insert path as
        // live retirement, original order — pruning semantics after a
        // recovery match a daemon that never crashed.
        let mut history = HistoryTable::default();
        for v in history_seed {
            history.insert_capped(v.id, Arc::new(v), cfg.history_cap);
        }
        let snapshot = Arc::new(SchedSnapshot::capture(&sched, None));
        Arc::new(Self {
            sched: Mutex::new(sched),
            snapshot: RwLock::new(snapshot),
            hub: WaitHub::default(),
            metrics: DaemonMetrics::default(),
            running: AtomicBool::new(true),
            start: Instant::now(),
            virtual_base,
            cfg,
            journal: journal.map(Mutex::new),
            manifests: RwLock::new(registry),
            tracked: Mutex::new(tracked),
            history: RwLock::new(history),
        })
    }

    /// Still serving?
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Request shutdown.
    pub fn shutdown(&self) {
        self.running.store(false, Ordering::SeqCst);
        // Parked waiters must observe the flag and fail their waits.
        self.hub.notify();
    }

    /// Target virtual time for the current wall clock (offset by the
    /// recovered instant: virtual time never rewinds across a restart).
    fn target_now(&self) -> SimTime {
        self.virtual_base + SimTime::from_secs_f64(self.start.elapsed().as_secs_f64() * self.cfg.speedup)
    }

    // ---- write path --------------------------------------------------------

    /// Run a mutating operation under the scheduler mutex, publish a fresh
    /// snapshot before releasing it, and account the lock hold time. Every
    /// scheduler write goes through here or [`Daemon::pace`]; the read path
    /// never takes this lock.
    fn with_sched_mut<T>(&self, f: impl FnOnce(&mut Scheduler) -> T) -> T {
        let mut sched = self.sched.lock().expect("scheduler poisoned");
        let t0 = Instant::now(); // hold time, not acquisition wait
        let out = f(&mut sched);
        self.publish_locked(&sched);
        let hold_ns = t0.elapsed().as_nanos() as u64;
        drop(sched);
        self.metrics.record_write_lock(hold_ns);
        out
    }

    /// Capture + swap the published snapshot. Must be called with the
    /// scheduler mutex held (that is what serializes publishes). Bumps the
    /// WAIT completion generation when dispatch or terminal progress landed.
    fn publish_locked(&self, sched: &Scheduler) {
        let prev = Arc::clone(&self.snapshot.read().expect("snapshot poisoned"));
        if prev.version == sched.change_version() && prev.virtual_now == sched.now() {
            return; // nothing moved, not even the clock
        }
        let next = Arc::new(SchedSnapshot::capture(sched, Some(&prev)));
        let progressed =
            next.stats.dispatches != prev.stats.dispatches || next.ended != prev.ended;
        *self.snapshot.write().expect("snapshot poisoned") = next;
        if progressed {
            self.hub.notify();
        }
    }

    /// Append one record to the journal (fsync'd per policy inside). Call
    /// with the scheduler mutex held, *before* the mutation the record
    /// describes — on `Err` the caller must neither mutate nor ack, so an
    /// acknowledged action always exists on disk first. A poisoned journal
    /// fails every subsequent admission the same way: the daemon degrades
    /// to read-only rather than silently dropping durability.
    fn journal_append(&self, rec: &JournalRecord) -> Result<(), ApiError> {
        let Some(journal) = &self.journal else {
            return Ok(());
        };
        let mut j = journal.lock().expect("journal lock poisoned");
        j.append(rec).map_err(|e| {
            ApiError::new(
                ErrorCode::Internal,
                format!("write-ahead journal append failed (request not acked): {e}"),
            )
        })
    }

    /// Checkpoint-truncate the journal when due. Called with the scheduler
    /// mutex held, after a successful admission. Checkpoint failure poisons
    /// the journal (subsequent admissions fail typed) but the admission
    /// that triggered it was already durable in the old segment, so nothing
    /// acked is lost.
    fn maybe_checkpoint_locked(&self, sched: &Scheduler) {
        let (Some(journal), Some(dcfg)) = (&self.journal, &self.cfg.durability) else {
            return;
        };
        let mut j = journal.lock().expect("journal lock poisoned");
        if j.is_poisoned() || !j.checkpoint_due(dcfg) {
            return;
        }
        let state = self.capture_checkpoint_locked(sched);
        if let Err(e) = j.checkpoint(&state) {
            eprintln!("spotcloud: journal checkpoint failed (journal now read-only): {e}");
        }
    }

    /// Capture the full durable state under the scheduler mutex. Live
    /// terminal jobs (ended but not yet retired) are captured as history
    /// views, not as live jobs — recovery re-queues every live job, and
    /// re-running a completed job would violate exactly-once.
    fn capture_checkpoint_locked(&self, sched: &Scheduler) -> CheckpointState {
        let registry = self.manifests.read().expect("manifests poisoned");
        let history = self.history.read().expect("history poisoned");
        let mut jobs = Vec::new();
        let mut views = history.ordered_views();
        for job in sched.jobs() {
            if job.state.is_terminal() {
                views.push(JobView::of(job, sched.log()));
            } else {
                jobs.push(CheckpointJob {
                    id: job.id.0,
                    state: job.state,
                    submit_time: job.submit_time,
                    requeue_count: job.requeue_count,
                    spec: job.spec.clone(),
                    log: sched
                        .log()
                        .for_job(job.id)
                        .map(|e| (e.time, e.kind))
                        .collect(),
                });
            }
        }
        CheckpointState {
            vtime: sched.now(),
            next_id: sched.jobs_signature().1,
            next_manifest_id: registry.next_id(),
            jobs,
            history: views,
            manifests: registry.iter().cloned().collect(),
        }
    }

    /// Advance the scheduler to the current wall-paced virtual time, harvest
    /// newly dispatched tracked jobs into the metrics, retire old terminal
    /// jobs into the history side-table, and publish.
    pub fn pace(&self) {
        self.with_sched_mut(|sched| {
            let target = self.target_now();
            if target > sched.now() {
                sched.run_until(target);
            }
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            let done: Vec<JobId> = tracked
                .iter()
                .copied()
                .filter(|&j| sched.log().last(j, LogKind::DispatchDone).is_some())
                .collect();
            for j in done {
                tracked.remove(&j);
                let rec = sched.log().first(j, LogKind::Recognized).expect("recognized");
                let dis = sched.log().last(j, LogKind::DispatchDone).expect("dispatched");
                self.metrics.record_sched_latency(dis.saturating_sub(rec).as_nanos());
            }
            drop(tracked);
            if let Some(grace) = self.cfg.retire_grace_secs {
                let retired = sched.retire_terminal(SimTime::from_secs_f64(grace));
                if !retired.is_empty() {
                    {
                        // Freeze the views *before* pruning the log — the
                        // view construction reads the retired jobs' last
                        // event-log records.
                        let mut history = self.history.write().expect("history poisoned");
                        for j in &retired {
                            history.insert_capped(
                                j.id.0,
                                Arc::new(JobView::of(j, sched.log())),
                                self.cfg.history_cap,
                            );
                        }
                    }
                    // Retired jobs' event-log entries are dead weight from
                    // here on (everything queryable lives in the frozen
                    // views): drop their indexes and let the log compact.
                    sched.prune_retired_log(retired.iter().map(|j| j.id));
                }
            }
        });
    }

    /// Spawn the pacer thread. Returns its join handle; the thread exits on
    /// shutdown.
    pub fn spawn_pacer(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let daemon = Arc::clone(self);
        std::thread::Builder::new()
            .name("spotcloud-pacer".into())
            .spawn(move || {
                while daemon.is_running() {
                    daemon.pace();
                    std::thread::sleep(std::time::Duration::from_millis(daemon.cfg.pacer_tick_ms));
                }
            })
            .expect("spawning pacer")
    }

    // ---- read path ---------------------------------------------------------

    /// The published read view (lock-free with respect to the scheduler:
    /// only the snapshot `RwLock` is touched, and only to clone an `Arc`).
    /// Counts toward the read-path metric — client-request use only.
    pub fn read_snapshot(&self) -> Arc<SchedSnapshot> {
        self.metrics.record_read_path();
        self.snapshot()
    }

    /// Unmetered snapshot access for internal machinery (WAIT admission and
    /// polling), so waiter polling doesn't pollute the read-path counter.
    fn snapshot(&self) -> Arc<SchedSnapshot> {
        Arc::clone(&self.snapshot.read().expect("snapshot poisoned"))
    }

    // ---- wire front door ---------------------------------------------------

    /// Handle one v1 request line; returns the rendered response body.
    /// (Compatibility surface — the transport uses
    /// [`Daemon::handle_line_versioned`].)
    pub fn handle_line(&self, line: &str) -> String {
        self.handle_line_versioned(line, ProtocolVersion::V1).0
    }

    /// Handle one request line under `version`, blocking for `WAIT`.
    /// Returns the rendered response and, for a successful `HELLO`, the
    /// version the connection speaks from the next request on (the `HELLO`
    /// response itself is already rendered in the negotiated version).
    pub fn handle_line_versioned(
        &self,
        line: &str,
        version: ProtocolVersion,
    ) -> (String, Option<ProtocolVersion>) {
        match self.handle_line_nonblocking(line, version) {
            LineOutcome::Done(resp, negotiated) => (resp, negotiated),
            LineOutcome::Parked(parked) => {
                let resp = self.block_on_wait(&parked.ticket);
                (self.finish_wait(&parked, resp), None)
            }
        }
    }

    /// Handle one request line without ever blocking the caller: a `WAIT`
    /// that cannot complete immediately comes back as
    /// [`LineOutcome::Parked`] for the transport to resume later.
    pub fn handle_line_nonblocking(&self, line: &str, version: ProtocolVersion) -> LineOutcome {
        let t0 = Instant::now();
        let (resp, render_version, negotiated) = match codec::parse_request(line, version) {
            Ok(req) => {
                self.metrics.record_command(req.command_name());
                if let Request::Wait { jobs, timeout_secs } = &req {
                    match self.begin_wait(jobs, *timeout_secs) {
                        WaitStart::Done(resp) => (resp, version, None),
                        WaitStart::Parked(ticket) => {
                            return LineOutcome::Parked(ParkedWait { ticket, version });
                        }
                    }
                } else if let Request::WaitEntry {
                    manifest,
                    entry,
                    timeout_secs,
                } = &req
                {
                    // Per-entry WAIT parks exactly like a job-list WAIT —
                    // the manifest/entry pair resolves to its id span
                    // first, so resolution errors come back immediately.
                    match self.resolve_entry_jobs(*manifest, *entry) {
                        Ok(jobs) => match self.begin_wait(&jobs, *timeout_secs) {
                            WaitStart::Done(resp) => (resp, version, None),
                            WaitStart::Parked(ticket) => {
                                return LineOutcome::Parked(ParkedWait { ticket, version });
                            }
                        },
                        Err(e) => (Response::Error(e), version, None),
                    }
                } else {
                    let negotiated = match &req {
                        Request::Hello(v) => Some(*v),
                        _ => None,
                    };
                    let resp = self.handle(req);
                    (resp, negotiated.unwrap_or(version), negotiated)
                }
            }
            Err(e) => (Response::Error(e), version, None),
        };
        let ok = !matches!(resp, Response::Error(_));
        self.metrics.record_request(ok, t0.elapsed().as_nanos() as u64);
        LineOutcome::Done(codec::render_response(&resp, render_version), negotiated)
    }

    /// Render a parked `WAIT`'s final response and account the request
    /// (wall latency measured from arrival, not resume).
    pub fn finish_wait(&self, parked: &ParkedWait, resp: Response) -> String {
        let ok = !matches!(resp, Response::Error(_));
        self.metrics
            .record_request(ok, parked.ticket.started.elapsed().as_nanos() as u64);
        codec::render_response(&resp, parked.version)
    }

    /// Handle one typed request. Total: failures come back as
    /// [`Response::Error`]. `WAIT` blocks (the transport-level
    /// [`Daemon::handle_line_nonblocking`] parks instead).
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Hello(v) => Response::Hello(v),
            Request::Shutdown => {
                self.shutdown();
                Response::ShuttingDown
            }
            Request::Submit(spec) => self.handle_submit(&spec),
            Request::MSubmit(manifest) => self.handle_msubmit(&manifest),
            Request::Scancel(id) => {
                let cancelled = self.with_sched_mut(|sched| {
                    if !sched.cancel(JobId(id)) {
                        return Ok(false);
                    }
                    // Cancel is mutate-then-append: the scheduler state is
                    // already changed, so a journal failure here leaves the
                    // cancel applied but *unacked* — the client retries and
                    // lands on the tolerant-replay path. This is the
                    // documented at-least-once edge (see PROTOCOL.md).
                    self.journal_append(&JournalRecord::Cancel {
                        vtime: sched.now(),
                        id,
                    })?;
                    self.maybe_checkpoint_locked(sched);
                    Ok::<_, ApiError>(true)
                });
                match cancelled {
                    Ok(true) => Response::Cancelled(id),
                    Ok(false) => Response::Error(ApiError::not_found(format!(
                        "unknown or finished job {id}"
                    ))),
                    Err(e) => Response::Error(e),
                }
            }
            Request::Squeue(filter) => self.handle_squeue(&filter),
            Request::Sjob(id) => self.handle_sjob(id),
            Request::Wait { jobs, timeout_secs } => match self.begin_wait(&jobs, timeout_secs) {
                WaitStart::Done(resp) => resp,
                WaitStart::Parked(ticket) => self.block_on_wait(&ticket),
            },
            Request::WaitEntry {
                manifest,
                entry,
                timeout_secs,
            } => match self.resolve_entry_jobs(manifest, entry) {
                Ok(jobs) => match self.begin_wait(&jobs, timeout_secs) {
                    WaitStart::Done(resp) => resp,
                    WaitStart::Parked(ticket) => self.block_on_wait(&ticket),
                },
                Err(e) => Response::Error(e),
            },
            Request::Resume(target) => self.handle_resume(&target),
            Request::Stats => Response::Stats(self.stats_snapshot()),
            Request::Util => Response::Util(self.util_snapshot()),
        }
    }

    /// Materialize the specs a submission creates: `count` repetitions of
    /// the paper's per-type expansion (individual → one spec per task).
    fn materialize(spec: &SubmitSpec) -> Vec<JobSpec> {
        let mut specs = Vec::new();
        for _ in 0..spec.count {
            let batch = match spec.qos {
                QosClass::Normal => crate::workload::interactive_burst(
                    UserId(spec.user),
                    spec.job_type,
                    spec.tasks,
                ),
                QosClass::Spot => vec![JobSpec::spot(UserId(spec.user), spec.job_type, spec.tasks)],
            };
            specs.extend(
                batch
                    .into_iter()
                    .map(|s| s.with_run_time(SimTime::from_secs_f64(spec.run_secs))),
            );
        }
        specs
    }

    fn handle_submit(&self, spec: &SubmitSpec) -> Response {
        // Degenerate shapes are typed errors at admission, on the typed
        // path too — not just at the codec (a `tasks=0` array job would
        // otherwise land unschedulable, and a `count=0` burst would ack an
        // empty id range as if it had submitted something).
        if spec.tasks == 0 {
            return Response::Error(ApiError::bad_arg("tasks", "0"));
        }
        if spec.count == 0 {
            return Response::Error(ApiError::bad_arg("count", "0"));
        }
        if !(spec.run_secs.is_finite() && spec.run_secs >= 0.0) {
            return Response::Error(ApiError::bad_arg("run_secs", &spec.run_secs.to_string()));
        }
        let expansion = match spec.qos {
            // Individual submissions expand to one job per task.
            QosClass::Normal if spec.job_type == crate::job::JobType::Individual => {
                spec.tasks as u64
            }
            _ => 1,
        };
        if spec.count as u64 * expansion > MAX_BATCH_JOBS {
            return Response::Error(ApiError::bad_arg(
                "count",
                &format!("{} (batch exceeds {MAX_BATCH_JOBS} jobs)", spec.count),
            ));
        }
        let specs = Self::materialize(spec);
        let batched = spec.count > 1;
        let total_jobs = specs.len() as u64;
        let ids = self.with_sched_mut(|sched| {
            // Keep the virtual clock caught up so submissions land "now"
            // (computed under the lock: a stale target would backdate the
            // submission by the lock-wait time × speedup).
            let target = self.target_now();
            if target > sched.now() {
                sched.run_until(target);
            }
            if self.journal.is_some() {
                // Write-ahead: journal the admission (as one synthesized
                // manifest entry — replay re-materializes the identical
                // spec list) *before* the scheduler mutates, so a journal
                // failure admits and acks nothing. The scheduler's id
                // assignment is deterministic, so the first id is known
                // before submission.
                let entry = ManifestEntry::new(spec.qos, spec.job_type, spec.tasks, spec.user)
                    .with_run_secs(spec.run_secs)
                    .with_count(spec.count);
                self.journal_append(&JournalRecord::Admit {
                    vtime: sched.now(),
                    first_id: sched.jobs_signature().1,
                    total_jobs,
                    manifest: None,
                    entries: vec![AdmitEntry { index: 0, entry }],
                })?;
            }
            let ids = if batched {
                // Batched: the whole burst arrives in this one RPC.
                sched.submit_batch(specs)
            } else {
                // Single spec: client-side serialization, as the paper's
                // launcher loop submits (one submit RPC apart).
                sched.submit_burst(specs)
            };
            self.maybe_checkpoint_locked(sched);
            Ok::<_, ApiError>(ids)
        });
        let ids = match ids {
            Ok(ids) => ids,
            Err(e) => return Response::Error(e),
        };
        self.metrics
            .jobs_submitted
            .fetch_add(ids.len() as u64, Ordering::Relaxed);
        if spec.qos == QosClass::Normal {
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            tracked.extend(ids.iter().copied());
        }
        let first = ids.first().map(|j| j.0).unwrap_or(0);
        let last = ids.last().map(|j| j.0).unwrap_or(0);
        Response::SubmitAck(SubmitAck {
            first,
            last,
            count: ids.len() as u64,
        })
    }

    /// Manifest admission: validate each entry independently, then land
    /// every accepted entry's jobs **atomically** — one scheduler lock, one
    /// batched arrival instant ([`Scheduler::submit_batch`]) — and report
    /// per-entry id ranges plus typed per-entry rejects (partial accept).
    fn handle_msubmit(&self, manifest: &Manifest) -> Response {
        if manifest.entries.len() > MAX_MANIFEST_ENTRIES {
            return Response::Error(ApiError::bad_arg(
                "entries",
                &format!("{} (cap {MAX_MANIFEST_ENTRIES})", manifest.entries.len()),
            ));
        }
        let mut rejected = Vec::new();
        let mut accepted_idx = Vec::new();
        let mut total_jobs = 0u64;
        for (i, entry) in manifest.entries.iter().enumerate() {
            match entry.validate() {
                Ok(()) => {
                    total_jobs += entry.jobs();
                    accepted_idx.push(i);
                }
                Err(error) => rejected.push(EntryReject {
                    index: i as u32,
                    error,
                }),
            }
        }
        if total_jobs > MAX_BATCH_JOBS {
            // The aggregate cap is a whole-request error: silently dropping
            // the tail of a manifest would be worse than refusing it.
            return Response::Error(ApiError::bad_arg(
                "manifest",
                &format!("materializes {total_jobs} jobs (batch cap {MAX_BATCH_JOBS})"),
            ));
        }
        // Materialize outside the lock; remember each entry's span so the
        // contiguous id range submit_batch assigns can be split back out.
        let mut specs = Vec::with_capacity(total_jobs as usize);
        let mut spans = Vec::with_capacity(accepted_idx.len());
        for &i in &accepted_idx {
            let batch = manifest.entries[i].materialize();
            spans.push((i, specs.len(), batch.len()));
            specs.extend(batch);
        }
        let (ids, manifest_id) = if specs.is_empty() {
            (Vec::new(), None)
        } else {
            // A manifest with at least one accepted entry gets a registry
            // id; the id is pre-read so the journal record carries it (the
            // registry assigns ids sequentially, and registration happens
            // under the same scheduler lock).
            let result = self.with_sched_mut(|sched| {
                // Keep the virtual clock caught up so the whole manifest
                // lands "now" (computed under the lock, same as SUBMIT).
                let target = self.target_now();
                if target > sched.now() {
                    sched.run_until(target);
                }
                let mid = self.manifests.read().expect("manifests poisoned").next_id();
                if self.journal.is_some() {
                    // Write-ahead, same contract as SUBMIT: the record
                    // lands durably before the scheduler or registry
                    // mutate, so a journal failure admits nothing.
                    let entries = spans
                        .iter()
                        .map(|&(i, _, _)| AdmitEntry {
                            index: i as u32,
                            entry: manifest.entries[i].clone(),
                        })
                        .collect();
                    self.journal_append(&JournalRecord::Admit {
                        vtime: sched.now(),
                        first_id: sched.jobs_signature().1,
                        total_jobs,
                        manifest: Some(mid),
                        entries,
                    })?;
                }
                let ids = sched.submit_batch(specs);
                let reg_spans = spans
                    .iter()
                    .map(|&(i, start, len)| ManifestSpan {
                        index: i as u32,
                        first: ids[start].0,
                        count: len as u64,
                        tag: manifest.entries[i].tag.clone(),
                    })
                    .collect();
                let registered = self
                    .manifests
                    .write()
                    .expect("manifests poisoned")
                    .register(reg_spans);
                debug_assert_eq!(registered, Some(mid));
                self.maybe_checkpoint_locked(sched);
                Ok::<_, ApiError>((ids, Some(mid)))
            });
            match result {
                Ok(pair) => pair,
                Err(e) => return Response::Error(e),
            }
        };
        debug_assert_eq!(ids.len() as u64, total_jobs);
        self.metrics
            .jobs_submitted
            .fetch_add(ids.len() as u64, Ordering::Relaxed);
        let mut accepted = Vec::with_capacity(spans.len());
        {
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            for &(i, start, len) in &spans {
                let entry_ids = &ids[start..start + len];
                if manifest.entries[i].qos == QosClass::Normal {
                    // Interactive entries feed the daemon's Figure-2
                    // latency histogram, like the legacy SUBMIT path.
                    tracked.extend(entry_ids.iter().copied());
                }
                accepted.push(EntryAck {
                    index: i as u32,
                    first: entry_ids.first().map(|j| j.0).unwrap_or(0),
                    last: entry_ids.last().map(|j| j.0).unwrap_or(0),
                    count: len as u64,
                });
            }
        }
        Response::ManifestAck(ManifestAck {
            accepted,
            rejected,
            jobs: ids.len() as u64,
            manifest: manifest_id,
        })
    }

    fn handle_squeue(&self, filter: &SqueueFilter) -> Response {
        let snap = self.read_snapshot();
        let states: Vec<JobState> = match filter.state {
            Some(s) => vec![s],
            None => vec![JobState::Pending, JobState::Running, JobState::Requeued],
        };
        let limit = filter.limit.unwrap_or(usize::MAX);
        let mut rows = Vec::new();
        'outer: for st in states {
            for v in snap.jobs_in_state(st) {
                if filter.user.is_some_and(|u| v.user != u) {
                    continue;
                }
                if filter.qos.is_some_and(|q| v.qos != q) {
                    continue;
                }
                rows.push(JobSummary {
                    id: v.id,
                    job_type: v.job_type,
                    tasks: v.tasks,
                    user: v.user,
                    qos: v.qos,
                    state: v.state,
                    tag: Some(Arc::clone(&v.tag)),
                });
                if rows.len() >= limit {
                    break 'outer;
                }
            }
        }
        Response::Jobs(rows)
    }

    fn handle_sjob(&self, id: u64) -> Response {
        let snap = self.read_snapshot();
        if let Some(v) = snap.job(id) {
            return Response::Job(Self::detail_of(v));
        }
        // Retired terminal jobs answer from the history side-table, so a
        // bounded published table does not break `SJOB` for old ids.
        if let Some(v) = self.history.read().expect("history poisoned").get(&id) {
            return Response::Job(Self::detail_of(v));
        }
        Response::Error(ApiError::not_found(format!("unknown job {id}")))
    }

    fn detail_of(v: &JobView) -> JobDetail {
        JobDetail {
            id: v.id,
            job_type: v.job_type,
            tasks: v.tasks,
            user: v.user,
            qos: v.qos,
            state: v.state,
            submit_secs: v.submit_secs,
            queue_secs: v.queue_secs,
            start_secs: v.start_secs,
            end_secs: v.end_secs,
            requeues: v.requeues,
            recognized_secs: v.recognized.map(SimTime::as_secs_f64),
            dispatched_secs: v.dispatched.map(SimTime::as_secs_f64),
            latency_ns: v.latency_ns(),
            tag: Some(Arc::clone(&v.tag)),
        }
    }

    // ---- RESUME: manifest re-attach ---------------------------------------

    /// `RESUME`: resolve a manifest (by id, or the latest under a tag) and
    /// report each accepted entry's settlement, so a reconnecting client
    /// collects exactly the not-yet-settled entries. An id missing from
    /// both the snapshot and the history table counts as settled — the
    /// history cap only ever evicts *retired* (terminal) jobs, which can
    /// never dispatch again.
    fn handle_resume(&self, target: &ResumeTarget) -> Response {
        let registry = self.manifests.read().expect("manifests poisoned");
        let found = match target {
            ResumeTarget::Manifest(id) => registry.get(*id),
            ResumeTarget::Tag(tag) => registry.by_tag(tag),
        };
        let Some(m) = found else {
            return Response::Error(ApiError::not_found(match target {
                ResumeTarget::Manifest(id) => format!("unknown manifest {id}"),
                ResumeTarget::Tag(tag) => format!("no manifest tagged {tag}"),
            }));
        };
        let snap = self.read_snapshot();
        let history = self.history.read().expect("history poisoned");
        let entries = m
            .spans
            .iter()
            .map(|span| {
                let settled = span
                    .ids()
                    .filter(|&id| {
                        snap.job(id)
                            .or_else(|| history.get(&id).map(Arc::as_ref))
                            .map_or(true, JobView::settled)
                    })
                    .count() as u64;
                ResumeEntry {
                    index: span.index,
                    first: span.first,
                    count: span.count,
                    settled,
                    tag: span.tag.clone(),
                }
            })
            .collect();
        Response::Resume(ResumeInfo {
            manifest: m.id,
            entries,
        })
    }

    /// Resolve a `WAIT manifest=<id> entry=<k>` pair to its job-id span.
    fn resolve_entry_jobs(&self, manifest: u64, entry: u32) -> Result<Vec<u64>, ApiError> {
        let registry = self.manifests.read().expect("manifests poisoned");
        match registry.span(manifest, entry) {
            Some(span) => Ok(span.ids().collect()),
            None => Err(ApiError::not_found(format!(
                "unknown manifest {manifest} entry {entry}"
            ))),
        }
    }

    // ---- WAIT: subscription model -----------------------------------------

    /// Admit a `WAIT`: validate, and either answer immediately (invalid
    /// timeout, unknown job, empty list, already settled) or park a ticket
    /// on the completion hub.
    pub fn begin_wait(&self, jobs: &[u64], timeout_secs: f64) -> WaitStart {
        if !(timeout_secs.is_finite() && (0.0..=MAX_WAIT_SECS).contains(&timeout_secs)) {
            return WaitStart::Done(Response::Error(ApiError::bad_arg(
                "timeout",
                &format!("{timeout_secs}"),
            )));
        }
        // Nothing to wait for: return immediately instead of blocking until
        // the timeout (regression: empty `jobs` used to hang/err).
        if jobs.is_empty() {
            return WaitStart::Done(Response::Wait(WaitResult {
                requested: 0,
                dispatched: 0,
                timed_out: false,
                latency_ns: 0,
            }));
        }
        let snap = self.snapshot();
        {
            let history = self.history.read().expect("history poisoned");
            for &id in jobs {
                // Retired jobs are terminal (settled), answered from
                // history below — only a never-seen id is unknown.
                if snap.job(id).is_none() && !history.contains_key(&id) {
                    return WaitStart::Done(Response::Error(ApiError::not_found(format!(
                        "unknown job {id}"
                    ))));
                }
            }
        }
        let (wv, pruned) = self.wait_view(&snap, jobs);
        if let Some(id) = pruned {
            // Evicted between the existence check above and this read.
            return WaitStart::Done(Response::Error(ApiError::not_found(format!(
                "unknown job {id}"
            ))));
        }
        if wv.settled {
            return WaitStart::Done(wait_response(jobs.len(), wv, false));
        }
        self.metrics.waits_parked.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        WaitStart::Parked(WaitTicket {
            jobs: jobs.to_vec(),
            deadline: now + Duration::from_secs_f64(timeout_secs),
            started: now,
        })
    }

    /// Evaluate a `WAIT` over the published snapshot **with the history
    /// side-table folded in**, so a job retired mid-wait (or before the
    /// request) still reports its dispatch and true latency instead of
    /// silently dropping to `dispatched=0`. The second value is `Some(id)`
    /// for an id found in neither place — admission checked existence, so
    /// mid-wait that means the record was evicted by the history cap.
    fn wait_view(&self, snap: &SchedSnapshot, ids: &[u64]) -> (WaitView, Option<u64>) {
        let history = self.history.read().expect("history poisoned");
        let mut pruned = None;
        let wv = wait_view_of(ids.iter().map(|&id| {
            let view = snap.job(id).or_else(|| history.get(&id).map(Arc::as_ref));
            if view.is_none() && pruned.is_none() {
                pruned = Some(id);
            }
            view
        }));
        (wv, pruned)
    }

    /// Poll a parked `WAIT` against the current snapshot: `Some` exactly
    /// once — when it settled, timed out, or the daemon is shutting down.
    pub fn poll_wait(&self, ticket: &WaitTicket) -> Option<Response> {
        let snap = self.snapshot();
        let (wv, pruned) = self.wait_view(&snap, &ticket.jobs);
        let resp = if let Some(id) = pruned {
            // The record was evicted by `history_cap` while we waited: its
            // dispatch facts are gone, so answer the documented typed
            // not_found rather than a fabricated `dispatched=0`.
            Response::Error(ApiError::not_found(format!(
                "job {id} was pruned from history while waiting"
            )))
        } else if wv.settled {
            wait_response(ticket.jobs.len(), wv, false)
        } else if Instant::now() >= ticket.deadline {
            wait_response(ticket.jobs.len(), wv, true)
        } else if !self.is_running() {
            Response::Error(ApiError::unsupported("daemon is shutting down"))
        } else {
            return None;
        };
        self.metrics.waits_resumed.fetch_add(1, Ordering::Relaxed);
        Some(resp)
    }

    /// Block the calling thread on a parked `WAIT`. Paces the scheduler
    /// itself between hub wakes, so it works with or without the pacer
    /// thread (exactly like the old polling `WAIT`, minus the busy loop:
    /// a `DispatchDone` notify ends the sleep early).
    fn block_on_wait(&self, ticket: &WaitTicket) -> Response {
        loop {
            self.pace();
            // Read the generation *after* pacing so our own publish cannot
            // spuriously end the sleep, but any concurrent publish can.
            let gen = self.hub.generation();
            if let Some(resp) = self.poll_wait(ticket) {
                return resp;
            }
            let remaining = ticket.deadline.saturating_duration_since(Instant::now());
            self.hub.wait_change(gen, remaining.min(WAIT_POLL));
        }
    }

    /// Current completion generation (server waiter thread).
    pub fn completion_generation(&self) -> u64 {
        self.hub.generation()
    }

    /// Park until the completion generation moves past `seen` or `timeout`
    /// elapses; returns the observed generation (server waiter thread).
    pub fn wait_completion(&self, seen: u64, timeout: Duration) -> u64 {
        self.hub.wait_change(seen, timeout)
    }

    /// Wake the waiter machinery without claiming progress (the server
    /// kicks this when it parks a new connection so its waiter thread
    /// re-computes the nearest deadline).
    pub fn kick_waiters(&self) {
        self.hub.notify();
    }

    /// Register a completion waker: invoked on every completion notify
    /// (dispatch/terminal progress, shutdown, kicks). The Linux reactor
    /// subscribes an eventfd write here so parked-`WAIT` progress wakes
    /// `epoll_wait` directly — no dedicated waiter thread. The callback
    /// must be cheap and must not call back into the daemon.
    pub fn subscribe_completions(&self, f: Box<dyn Fn() + Send + Sync>) -> u64 {
        self.hub.subscribe(f)
    }

    /// Remove a waker registered with [`Daemon::subscribe_completions`].
    pub fn unsubscribe_completions(&self, id: u64) {
        self.hub.unsubscribe(id)
    }

    /// Fail a parked wait without waiting (waiter-registry overflow or a
    /// park/shutdown race). Counts as its one resolution.
    pub fn reject_wait(&self, _ticket: &WaitTicket, why: &str) -> Response {
        self.metrics.waits_resumed.fetch_add(1, Ordering::Relaxed);
        Response::Error(ApiError::unsupported(why))
    }

    fn stats_snapshot(&self) -> StatsSnapshot {
        let snap = self.read_snapshot();
        let st = &snap.stats;
        let hist = self.metrics.sched_latency();
        StatsSnapshot {
            virtual_now_secs: snap.virtual_now.as_secs_f64(),
            dispatches: st.dispatches,
            preemptions: st.preemptions,
            requeues: st.requeues,
            cron_passes: st.cron_passes,
            main_passes: st.main_passes,
            backfill_passes: st.backfill_passes,
            triggered_passes: st.triggered_passes,
            score_batches: st.score_batches,
            jobs_scored: st.jobs_scored,
            scorer: snap.scorer.to_string(),
            requests_ok: self.metrics.requests_ok.load(Ordering::Relaxed),
            requests_err: self.metrics.requests_err.load(Ordering::Relaxed),
            jobs_submitted: self.metrics.jobs_submitted.load(Ordering::Relaxed),
            sched_latency_count: hist.count(),
            sched_latency_p50_ns: hist.p50(),
            commands: self
                .metrics
                .command_counts()
                .into_iter()
                .map(|(cmd, n)| (cmd.to_ascii_lowercase(), n))
                .collect(),
            contention: Some(self.contention_stats()),
        }
    }

    /// Lock-path contention counters for the STATS v2 extension.
    fn contention_stats(&self) -> ContentionStats {
        let lock_hold = self.metrics.lock_hold();
        ContentionStats {
            read_path_ops: self.metrics.read_path_ops.load(Ordering::Relaxed),
            write_locks: self.metrics.write_locks.load(Ordering::Relaxed),
            waits_parked: self.metrics.waits_parked.load(Ordering::Relaxed),
            waits_resumed: self.metrics.waits_resumed.load(Ordering::Relaxed),
            lock_hold_count: lock_hold.count(),
            lock_hold_p50_ns: lock_hold.p50(),
            lock_hold_p99_ns: lock_hold.p99(),
            lock_hold_max_ns: lock_hold.max(),
        }
    }

    fn util_snapshot(&self) -> UtilSnapshot {
        let snap = self.read_snapshot();
        UtilSnapshot {
            utilization: snap.cluster.utilization,
            idle_cores: snap.cluster.idle_cores,
            idle_nodes: snap.cluster.idle_nodes,
            total_cores: snap.cluster.total_cores,
            pending: snap.pending,
            running: snap.running,
        }
    }

    /// Lock and inspect the scheduler (tests + e2e reporting).
    pub fn with_scheduler<T>(&self, f: impl FnOnce(&Scheduler) -> T) -> T {
        let sched = self.sched.lock().expect("scheduler poisoned");
        f(&sched)
    }
}

/// Build the `WAIT` response for a settled/timed-out view.
fn wait_response(requested: usize, wv: WaitView, timed_out: bool) -> Response {
    Response::Wait(WaitResult {
        requested: requested as u32,
        dispatched: wv.dispatched,
        timed_out,
        latency_ns: wv.latency_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::manifest::{ManifestBuilder, ManifestEntry};
    use crate::job::JobType;
    use crate::sim::SchedCosts;

    fn daemon() -> Arc<Daemon> {
        daemon_with(DaemonConfig {
            speedup: 10_000.0, // tests shouldn't wait on the wall clock
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        })
    }

    fn daemon_with(cfg: DaemonConfig) -> Arc<Daemon> {
        Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
    }

    #[test]
    fn ping_and_stats() {
        let d = daemon();
        assert_eq!(d.handle_line("PING"), "OK pong");
        assert!(d.handle_line("STATS").contains("virtual_now"));
        // Typed path.
        assert_eq!(d.handle(Request::Ping), Response::Pong);
        match d.handle(Request::Stats) {
            Response::Stats(s) => assert_eq!(s.scorer, "native"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn submit_runs_to_dispatch() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal triple 608 1 60");
        assert!(resp.starts_with("OK jobs="), "{resp}");
        // Pace until dispatch shows up in metrics.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while d.metrics.sched_latency().count() == 0 {
            assert!(Instant::now() < deadline, "job never dispatched");
            d.pace();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        // Baseline triple-mode latency is sub-second of *virtual* time.
        assert!(h.max() < 2_000_000_000, "virtual latency {}ns", h.max());
    }

    #[test]
    fn squeue_lists_jobs() {
        let d = daemon();
        d.handle_line("SUBMIT spot triple 320 9 600");
        let out = d.handle_line("SQUEUE");
        assert!(out.contains("triple-mode 320 user9 spot"), "{out}");
    }

    #[test]
    fn squeue_filters_apply() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Normal,
            JobType::Array,
            16,
            1,
        )));
        let all = match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(all.len(), 2);
        let spot_only = match d.handle(Request::Squeue(SqueueFilter {
            qos: Some(QosClass::Spot),
            ..Default::default()
        })) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(spot_only.len(), 1);
        assert_eq!(spot_only[0].user, 9);
        let limited = match d.handle(Request::Squeue(SqueueFilter {
            limit: Some(1),
            ..Default::default()
        })) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(limited.len(), 1);
    }

    #[test]
    fn batch_submit_creates_count_jobs_in_one_request() {
        let d = daemon();
        let resp = d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 3)
                .with_run_secs(60.0)
                .with_count(10_000),
        ));
        match resp {
            Response::SubmitAck(ack) => {
                assert_eq!(ack.count, 10_000);
                assert_eq!(ack.last - ack.first + 1, 10_000);
            }
            other => panic!("{other:?}"),
        }
        // An oversized batch is rejected with a typed error.
        match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 100, 3).with_count(100_000),
        )) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::BadArg),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn manifest_lands_heterogeneous_entries_atomically_with_per_entry_ids() {
        // The acceptance workload: a 10k-entry mixed manifest — interactive
        // AND spot, all three job types, more than three users (the shared
        // generator in workload::manifests, also what the CI bench gate
        // drives) — lands in ONE request with per-entry contiguous ranges.
        let d = daemon();
        let manifest = crate::workload::manifests::mixed(7, 10_000, 5);
        assert_eq!(manifest.entries.len(), 10_000);
        let writes_before = d.metrics.write_locks.load(Ordering::Relaxed);
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        // One RPC, one scheduler lock for the whole heterogeneous batch.
        assert_eq!(d.metrics.write_locks.load(Ordering::Relaxed), writes_before + 1);
        assert_eq!(ack.rejected.len(), 0, "{:?}", ack.rejected.first());
        assert_eq!(ack.accepted.len(), 10_000);
        assert_eq!(ack.jobs, 10_000);
        assert_eq!(d.metrics.jobs_submitted.load(Ordering::Relaxed), 10_000);
        // Per-entry ranges are contiguous, in order, and disjoint.
        let mut next = ack.accepted[0].first;
        for (k, acc) in ack.accepted.iter().enumerate() {
            assert_eq!(acc.index as usize, k);
            assert_eq!(acc.first, next, "entry {k} range not contiguous");
            assert_eq!(acc.last - acc.first + 1, acc.count);
            next = acc.last + 1;
        }
        d.with_scheduler(|sched| sched.check_invariants().unwrap());
    }

    #[test]
    fn manifest_partial_accept_rejects_bad_entries_and_admits_the_rest() {
        let d = daemon();
        let manifest = ManifestBuilder::new()
            .interactive(1, JobType::Array, 64)
            .entry(ManifestEntry::new(QosClass::Normal, JobType::Array, 0, 1)) // tasks=0
            .spot(9, JobType::TripleMode, 320)
            .entry(ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_count(0))
            .entry(
                ManifestEntry::new(QosClass::Normal, JobType::Individual, 4, 2)
                    .with_cores_per_task(0),
            )
            .entry(ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_tag("bad tag"))
            .build();
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(ack.accepted.len(), 2);
        assert_eq!(ack.jobs, 2);
        assert_eq!(
            ack.rejected.iter().map(|r| r.index).collect::<Vec<_>>(),
            vec![1, 3, 4, 5]
        );
        for r in &ack.rejected {
            assert_eq!(r.error.code, super::super::api::ErrorCode::BadArg, "{r:?}");
        }
        // The accepted entries are live: both jobs are in the queue/table.
        for acc in &ack.accepted {
            assert!(matches!(d.handle(Request::Sjob(acc.first)), Response::Job(_)));
        }
    }

    #[test]
    fn empty_manifest_acks_zero_without_locking_the_scheduler() {
        let d = daemon();
        let writes_before = d.metrics.write_locks.load(Ordering::Relaxed);
        match d.handle(Request::MSubmit(Manifest::default())) {
            Response::ManifestAck(a) => {
                assert_eq!(a.accepted.len(), 0);
                assert_eq!(a.jobs, 0);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(d.metrics.write_locks.load(Ordering::Relaxed), writes_before);
    }

    #[test]
    fn manifest_aggregate_job_cap_is_a_whole_request_error() {
        let d = daemon();
        // Two entries, each under the per-entry cap, together above it.
        let big = ManifestEntry::new(QosClass::Normal, JobType::Individual, 1, 1)
            .with_count((MAX_BATCH_JOBS / 2 + 1) as u32);
        let manifest = ManifestBuilder::new()
            .entry(big.clone())
            .entry(big)
            .build();
        match d.handle(Request::MSubmit(manifest)) {
            Response::Error(e) => {
                assert_eq!(e.code, super::super::api::ErrorCode::BadArg);
                assert!(e.message.contains("batch cap"), "{e}");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(d.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn manifest_tags_flow_to_squeue_and_sjob() {
        let d = daemon();
        let manifest = ManifestBuilder::new()
            .spot(9, JobType::TripleMode, 320)
            .last(|e| e.with_tag("spot-backlog"))
            .build();
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        let id = ack.accepted[0].first;
        match d.handle(Request::Sjob(id)) {
            Response::Job(detail) => assert_eq!(detail.tag.as_deref(), Some("spot-backlog")),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => {
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].tag.as_deref(), Some("spot-backlog"));
            }
            other => panic!("{other:?}"),
        }
        // The v2 wire carries the tag end to end.
        let (wire, _) = d.handle_line_versioned(&format!("SJOB id={id}"), ProtocolVersion::V2);
        assert!(wire.contains("tag=spot-backlog"), "{wire}");
    }

    #[test]
    fn manifest_interactive_entries_feed_the_latency_histogram() {
        let d = daemon();
        let manifest = ManifestBuilder::new()
            .interactive(1, JobType::TripleMode, 608)
            .last(|e| e.with_run_secs(60.0).with_tag("fig2-live"))
            .build();
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: ack.job_ids(),
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 1);
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1, "manifest submissions must be tracked");
        assert_eq!(h.max(), wait.latency_ns);
    }

    #[test]
    fn degenerate_typed_submits_are_rejected_with_typed_errors() {
        // Regression: the typed path used to bypass the codec's checks —
        // tasks=0 landed no-op/unschedulable jobs, count=0 acked nothing.
        let d = daemon();
        for spec in [
            SubmitSpec {
                tasks: 0,
                ..SubmitSpec::new(QosClass::Normal, JobType::Array, 1, 1)
            },
            SubmitSpec::new(QosClass::Normal, JobType::Array, 64, 1).with_count(0),
            SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 64, 9).with_run_secs(f64::NAN),
        ] {
            match d.handle(Request::Submit(spec.clone())) {
                Response::Error(e) => {
                    assert_eq!(e.code, super::super::api::ErrorCode::BadArg, "{spec:?}")
                }
                other => panic!("{spec:?} -> {other:?}"),
            }
        }
        assert_eq!(d.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
        match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => assert!(rows.is_empty(), "{rows:?}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scancel_pending_job() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal array 64 1 600");
        let id: u64 = resp
            .split("jobs=")
            .nth(1)
            .unwrap()
            .split('-')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let out = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out.starts_with("OK cancelled"), "{out}");
        // Cancelling again fails gracefully with a typed NotFound.
        match d.handle(Request::Scancel(id)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        let out2 = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out2.starts_with("ERR"), "{out2}");
    }

    #[test]
    fn sjob_reports_detail_and_latency() {
        let d = daemon();
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(60.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 1);
        match d.handle(Request::Sjob(ack.first)) {
            Response::Job(detail) => {
                assert_eq!(detail.id, ack.first);
                assert_eq!(detail.latency_ns, Some(wait.latency_ns));
                assert!(detail.dispatched_secs.is_some());
            }
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(999_999)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_latency_matches_metrics_histogram() {
        let d = daemon();
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(60.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        // WAIT paces the daemon itself, so the histogram harvest happened.
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), wait.latency_ns, "WAIT must report the histogram's value");
    }

    #[test]
    fn wait_on_unknown_job_is_not_found() {
        let d = daemon();
        match d.handle(Request::Wait {
            jobs: vec![12345],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_on_cancelled_job_returns_without_timeout() {
        let d = daemon();
        // A job too large for the user limit would pend forever; cancel it
        // and WAIT must return promptly with dispatched=0.
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Array, 64, 1).with_run_secs(600.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert!(matches!(
            d.handle(Request::Scancel(ack.first)),
            Response::Cancelled(_)
        ));
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 5.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 0);
    }

    #[test]
    fn wait_on_empty_job_list_returns_immediately() {
        // Regression: WAIT with an empty jobs list must not block until the
        // timeout (or error) — there is nothing to wait for.
        let d = daemon();
        let t0 = Instant::now();
        match d.handle(Request::Wait {
            jobs: vec![],
            timeout_secs: 30.0,
        }) {
            Response::Wait(w) => {
                assert_eq!(w.requested, 0);
                assert_eq!(w.dispatched, 0);
                assert!(!w.timed_out);
                assert_eq!(w.latency_ns, 0);
            }
            other => panic!("{other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "empty WAIT must not block"
        );
    }

    #[test]
    fn read_requests_never_take_the_scheduler_lock() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        let writes_before = d.metrics.write_locks.load(Ordering::Relaxed);
        let reads_before = d.metrics.read_path_ops.load(Ordering::Relaxed);
        for _ in 0..50 {
            assert!(matches!(
                d.handle(Request::Squeue(SqueueFilter::default())),
                Response::Jobs(_)
            ));
            assert!(matches!(d.handle(Request::Stats), Response::Stats(_)));
            assert!(matches!(d.handle(Request::Util), Response::Util(_)));
            assert!(matches!(d.handle(Request::Sjob(1)), Response::Job(_)));
        }
        assert_eq!(
            d.metrics.write_locks.load(Ordering::Relaxed),
            writes_before,
            "a read-only request acquired the scheduler write mutex"
        );
        assert!(d.metrics.read_path_ops.load(Ordering::Relaxed) >= reads_before + 200);
    }

    #[test]
    fn bad_request_counts_as_error() {
        let d = daemon();
        let out = d.handle_line("SUBMIT nope nope nope nope");
        assert!(out.starts_with("ERR"));
        assert_eq!(d.metrics.requests_err.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn per_command_counters_accumulate() {
        let d = daemon();
        d.handle_line("PING");
        d.handle_line("PING");
        d.handle_line("SQUEUE");
        match d.handle(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.commands.get("ping").copied(), Some(2));
                assert_eq!(s.commands.get("squeue").copied(), Some(1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hello_negotiates_v2_rendering() {
        let d = daemon();
        let (resp, negotiated) = d.handle_line_versioned("HELLO v2", ProtocolVersion::V1);
        assert_eq!(resp, "OK kind=hello proto=v2");
        assert_eq!(negotiated, Some(ProtocolVersion::V2));
        let (resp, _) = d.handle_line_versioned("PING", ProtocolVersion::V2);
        assert_eq!(resp, "OK kind=pong");
    }

    #[test]
    fn util_reports_cluster() {
        let d = daemon();
        let out = d.handle_line("UTIL");
        assert!(out.contains("total_cores=608"), "{out}");
        assert!(out.contains("utilization=0.0000"), "{out}");
    }

    #[test]
    fn shutdown_flips_flag() {
        let d = daemon();
        assert!(d.is_running());
        assert!(d.handle_line("SHUTDOWN").starts_with("OK"));
        assert!(!d.is_running());
    }

    #[test]
    fn stats_v2_exposes_contention_counters() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        d.handle(Request::Squeue(SqueueFilter::default()));
        // Typed: the block is populated and consistent with the metrics.
        match d.handle(Request::Stats) {
            Response::Stats(s) => {
                let c = s.contention.expect("daemon always fills contention");
                assert!(c.write_locks >= 1, "{c:?}");
                assert!(c.read_path_ops >= 1, "{c:?}");
                assert_eq!(c.lock_hold_count, c.write_locks, "{c:?}");
            }
            other => panic!("{other:?}"),
        }
        // Wire: v2 carries the extension keys and round-trips; v1 stays on
        // the original key set.
        let (v2, _) = d.handle_line_versioned("STATS", super::ProtocolVersion::V2);
        assert!(v2.contains("read_path_ops="), "{v2}");
        assert!(v2.contains("lock_hold_p99_ns="), "{v2}");
        match codec::parse_response(&v2, super::ProtocolVersion::V2).unwrap() {
            Response::Stats(s) => assert!(s.contention.is_some()),
            other => panic!("{other:?}"),
        }
        let v1 = d.handle_line("STATS");
        assert!(!v1.contains("read_path_ops="), "{v1}");
    }

    #[test]
    fn retired_jobs_leave_squeue_but_sjob_answers_from_history() {
        // Aggressive retirement: 5 virtual seconds of grace at 10k×
        // speedup. The job completes after 1 virtual second and must leave
        // the published table shortly after.
        let d = daemon_with(DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(5.0),
            ..DaemonConfig::default()
        });
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(1.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        // Pace until the job is retired from the snapshot.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            d.pace();
            if d.read_snapshot().job(ack.first).is_none() {
                break;
            }
            assert!(Instant::now() < deadline, "job was never retired");
            std::thread::sleep(Duration::from_millis(2));
        }
        // Gone from every SQUEUE listing, including state=completed.
        match d.handle(Request::Squeue(SqueueFilter {
            state: Some(JobState::Completed),
            ..Default::default()
        })) {
            Response::Jobs(rows) => assert!(rows.is_empty(), "{rows:?}"),
            other => panic!("{other:?}"),
        }
        // SJOB still answers, from history, with terminal detail intact.
        match d.handle(Request::Sjob(ack.first)) {
            Response::Job(detail) => {
                assert_eq!(detail.id, ack.first);
                assert_eq!(detail.state, JobState::Completed);
                assert!(detail.end_secs.is_some());
                assert_eq!(detail.latency_ns, Some(wait.latency_ns));
            }
            other => panic!("{other:?}"),
        }
        // WAIT on the retired job settles from history with the real
        // dispatch count and latency (not a silent dispatched=0).
        match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 5.0,
        }) {
            Response::Wait(w) => {
                assert!(!w.timed_out);
                assert_eq!(w.dispatched, 1, "retired job lost its dispatch: {w:?}");
                assert_eq!(w.latency_ns, wait.latency_ns);
            }
            other => panic!("{other:?}"),
        }
        // A genuinely unknown id is still NotFound.
        match d.handle(Request::Sjob(999_999)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn history_cap_prunes_oldest_retired_jobs_and_their_log() {
        // Three short jobs with staggered run times end (and so retire) in
        // submission order; a cap of 2 must evict the first-retired record.
        let d = daemon_with(DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(2.0),
            history_cap: Some(2),
            durability: None,
        });
        let mut ids = Vec::new();
        for run in [1.0, 2.0, 3.0] {
            let ack = match d.handle(Request::Submit(
                SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(run),
            )) {
                Response::SubmitAck(a) => a,
                other => panic!("{other:?}"),
            };
            let wait = match d.handle(Request::Wait {
                jobs: vec![ack.first],
                timeout_secs: 10.0,
            }) {
                Response::Wait(w) => w,
                other => panic!("{other:?}"),
            };
            assert!(!wait.timed_out);
            ids.push(ack.first);
        }
        // Pace until all three left the published table.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            d.pace();
            let snap = d.read_snapshot();
            if ids.iter().all(|&id| snap.job(id).is_none()) {
                break;
            }
            assert!(Instant::now() < deadline, "jobs were never retired");
            std::thread::sleep(Duration::from_millis(2));
        }
        // The cap held: at most 2 history records, the oldest pruned.
        assert!(d.history.read().expect("history").len() <= 2);
        match d.handle(Request::Sjob(ids[0])) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("pruned job must be not_found: {other:?}"),
        }
        match d.handle(Request::Sjob(ids[2])) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Completed),
            other => panic!("{other:?}"),
        }
        // WAIT on a pruned id is the same typed not_found.
        match d.handle(Request::Wait {
            jobs: vec![ids[0]],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        // Retirement pruned the event log's per-job indexes too.
        d.with_scheduler(|sched| {
            for &id in &ids {
                assert!(
                    sched.log().last(JobId(id), LogKind::DispatchDone).is_none(),
                    "retired job {id} kept log entries"
                );
            }
        });
    }

    // ---- durability -------------------------------------------------------

    use crate::coordinator::journal::FsyncPolicy;
    use crate::testkit::crash::{faulty_durability, TempDir};

    /// A journaling daemon whose virtual clock never advances (speedup 0):
    /// admitted jobs stay pending, so settlement state is deterministic.
    fn frozen_daemon_with_journal(dcfg: DurabilityConfig) -> Arc<Daemon> {
        daemon_with(DaemonConfig {
            speedup: 0.0,
            pacer_tick_ms: 1,
            durability: Some(dcfg),
            ..DaemonConfig::default()
        })
    }

    #[test]
    fn msubmit_ack_carries_the_manifest_id_and_resume_reports_pending() {
        let tmp = TempDir::new("spotcloud-daemon-resume");
        let d = frozen_daemon_with_journal(
            DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Never),
        );
        let m = ManifestBuilder::new()
            .interactive(1, JobType::Array, 8)
            .last(|e| e.with_tag("nightly"))
            .spot(9, JobType::Array, 64)
            .build();
        let ack = match d.handle(Request::MSubmit(m)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(ack.manifest, Some(1), "first registered manifest id");
        // Resume by tag finds it; nothing has dispatched (frozen clock).
        let info = match d.handle(Request::Resume(ResumeTarget::Tag("nightly".into()))) {
            Response::Resume(info) => info,
            other => panic!("{other:?}"),
        };
        assert_eq!(info.manifest, 1);
        assert_eq!(info.entries.len(), 2);
        for e in &info.entries {
            assert_eq!(e.settled, 0, "frozen daemon cannot have settled jobs");
        }
        assert_eq!(info.pending_entries().count(), 2);
        // Resume by id is the same view.
        match d.handle(Request::Resume(ResumeTarget::Manifest(1))) {
            Response::Resume(by_id) => assert_eq!(by_id, info),
            other => panic!("{other:?}"),
        }
        // Unknown targets are typed not_found.
        for bad in [
            Request::Resume(ResumeTarget::Tag("other".into())),
            Request::Resume(ResumeTarget::Manifest(99)),
        ] {
            match d.handle(bad) {
                Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
                other => panic!("{other:?}"),
            }
        }
        // Per-entry WAIT resolves the span (times out: nothing dispatches),
        // and an unknown entry index is not_found.
        match d.handle(Request::WaitEntry {
            manifest: 1,
            entry: 0,
            timeout_secs: 0.0,
        }) {
            Response::Wait(w) => {
                assert!(w.timed_out);
                // One array job (8 tasks materialize into a single job).
                assert_eq!(w.requested, 1);
                assert_eq!(w.dispatched, 0);
            }
            other => panic!("{other:?}"),
        }
        match d.handle(Request::WaitEntry {
            manifest: 1,
            entry: 7,
            timeout_secs: 0.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn recovery_replays_admissions_manifests_and_cancels() {
        let tmp = TempDir::new("spotcloud-daemon-recover");
        let cfg = DaemonConfig {
            speedup: 0.0,
            pacer_tick_ms: 1,
            durability: Some(DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always)),
            ..DaemonConfig::default()
        };
        let (first_span, spot_id);
        {
            let d = daemon_with(cfg.clone());
            let m = ManifestBuilder::new()
                .interactive(1, JobType::Array, 8)
                .last(|e| e.with_tag("replayed"))
                .build();
            let ack = match d.handle(Request::MSubmit(m)) {
                Response::ManifestAck(a) => a,
                other => panic!("{other:?}"),
            };
            first_span = (ack.accepted[0].first, ack.accepted[0].count);
            let spot = match d.handle(Request::Submit(SubmitSpec::new(
                QosClass::Spot,
                JobType::Array,
                16,
                9,
            ))) {
                Response::SubmitAck(a) => a,
                other => panic!("{other:?}"),
            };
            spot_id = spot.first;
            match d.handle(Request::Scancel(spot_id)) {
                Response::Cancelled(id) => assert_eq!(id, spot_id),
                other => panic!("{other:?}"),
            }
            d.shutdown();
        }
        let (d, report) = Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
        .expect("recovery");
        assert_eq!(report.admits_replayed, 2);
        assert_eq!(report.cancels_replayed, 1);
        assert_eq!(report.manifests_restored, 1);
        // The acked ids resolve to the same jobs after replay.
        match d.handle(Request::Sjob(first_span.0)) {
            Response::Job(detail) => assert_eq!(detail.qos, QosClass::Normal),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(spot_id)) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Cancelled),
            other => panic!("{other:?}"),
        }
        // Resume-by-tag still resolves with the original id span.
        let info = match d.handle(Request::Resume(ResumeTarget::Tag("replayed".into()))) {
            Response::Resume(info) => info,
            other => panic!("{other:?}"),
        };
        assert_eq!(info.entries[0].first, first_span.0);
        assert_eq!(info.entries[0].count, first_span.1);
        // New submissions continue the id sequence — nothing is reused.
        match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            4,
            9,
        ))) {
            Response::SubmitAck(a) => assert_eq!(a.first, report.next_id),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn journal_append_failure_admits_nothing_and_degrades_to_read_only() {
        let tmp = TempDir::new("spotcloud-daemon-fault");
        let d = frozen_daemon_with_journal(faulty_durability(
            tmp.path(),
            FsyncPolicy::Always,
            crate::coordinator::FaultPoint::AfterAppend,
        ));
        match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            8,
            9,
        ))) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::Internal),
            other => panic!("a journal fault must fail the admission: {other:?}"),
        }
        // Write-ahead means no scheduler mutation happened.
        let snap = d.read_snapshot();
        assert_eq!(snap.pending + snap.running, 0, "nothing was admitted");
        // The poisoned journal keeps failing admissions (read-only daemon)
        // rather than silently dropping durability.
        match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            8,
            9,
        ))) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::Internal),
            other => panic!("{other:?}"),
        }
        // Reads still serve.
        assert_eq!(d.handle(Request::Ping), Response::Pong);
    }

    #[test]
    fn pruned_ids_keep_their_typed_semantics_across_recovery() {
        // Satellite regression: history_cap pruning + event-log pruning must
        // compose with journal checkpoint-truncation — a daemon that pruned,
        // checkpointed, crashed, and recovered answers SJOB/WAIT on
        // pre-crash ids exactly like one that never crashed.
        let tmp = TempDir::new("spotcloud-daemon-prune-recover");
        let cfg = DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(2.0),
            history_cap: Some(2),
            durability: Some(
                DurabilityConfig::new(tmp.path())
                    .with_fsync(FsyncPolicy::Never)
                    .with_checkpoint_every(1),
            ),
        };
        let mut ids = Vec::new();
        {
            let d = daemon_with(cfg.clone());
            for run in [1.0, 2.0, 3.0] {
                let ack = match d.handle(Request::Submit(
                    SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1)
                        .with_run_secs(run),
                )) {
                    Response::SubmitAck(a) => a,
                    other => panic!("{other:?}"),
                };
                let wait = match d.handle(Request::Wait {
                    jobs: vec![ack.first],
                    timeout_secs: 10.0,
                }) {
                    Response::Wait(w) => w,
                    other => panic!("{other:?}"),
                };
                assert!(!wait.timed_out);
                ids.push(ack.first);
            }
            // Pace until all three retired (and the cap pruned the oldest).
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                d.pace();
                let snap = d.read_snapshot();
                if ids.iter().all(|&id| snap.job(id).is_none()) {
                    break;
                }
                assert!(Instant::now() < deadline, "jobs were never retired");
                std::thread::sleep(Duration::from_millis(2));
            }
            // One more admission checkpoints the pruned state into the
            // journal (checkpoint_every = 1).
            match d.handle(Request::Submit(SubmitSpec::new(
                QosClass::Spot,
                JobType::Array,
                8,
                9,
            ))) {
                Response::SubmitAck(_) => {}
                other => panic!("{other:?}"),
            }
            d.shutdown();
        }
        let (d, report) = Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
        .expect("recovery");
        assert!(report.history_restored <= 2, "{report}");
        // The pruned id is the same typed not_found as before the crash…
        match d.handle(Request::Sjob(ids[0])) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("pruned id must stay not_found after recovery: {other:?}"),
        }
        match d.handle(Request::Wait {
            jobs: vec![ids[0]],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        // …and the retained history ids still answer, exactly once, with
        // their settled pre-crash state.
        match d.handle(Request::Sjob(ids[2])) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Completed),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Wait {
            jobs: vec![ids[2]],
            timeout_secs: 1.0,
        }) {
            Response::Wait(w) => {
                assert!(!w.timed_out, "settled history job must not re-wait");
                assert_eq!(w.dispatched, 1);
            }
            other => panic!("{other:?}"),
        }
    }
}
